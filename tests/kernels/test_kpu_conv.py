"""KPU conv kernel vs XLA conv oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.kpu_conv import kpu_conv, kpu_conv_ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@given(
    hw=st.sampled_from([5, 8, 12, 16]),
    cin=st.sampled_from([3, 8, 16]),
    cout=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
@settings(max_examples=25, deadline=None)
def test_kpu_conv_matches_ref(hw, cin, cout, k, stride, dtype):
    k1, k2 = jax.random.split(jax.random.key(0))
    x = _rand(k1, (2, hw, hw, cin), dtype)
    w = _rand(k2, (k, k, cin, cout), dtype)
    got = kpu_conv(x, w, stride=stride)
    want = kpu_conv_ref(x, w, stride=stride)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_kpu_paper_example_5x5_3x3_2px():
    """Paper Fig. 5: 5x5 feature map, 3x3 kernel, multi-pixel processing."""
    k1, k2 = jax.random.split(jax.random.key(1))
    x = _rand(k1, (1, 5, 5, 3))
    w = _rand(k2, (3, 3, 3, 8))
    got = kpu_conv(x, w, stride=1)
    np.testing.assert_allclose(got, kpu_conv_ref(x, w), rtol=1e-4, atol=1e-4)


def test_kpu_stride2_prunes_phases():
    """Stride 2 output == every-2nd-window of stride-1 output (§II-E:
    pruned phases produce exactly the skipped windows)."""
    k1, k2 = jax.random.split(jax.random.key(2))
    x = _rand(k1, (1, 8, 8, 4))
    w = _rand(k2, (3, 3, 4, 8))
    s1 = kpu_conv(x, w, stride=1)
    s2 = kpu_conv(x, w, stride=2)
    # SAME padding for k=3: s=1 pads (1,1); s=2 on even size pads (0,1),
    # so the phase alignment offset is 1 row/col.
    np.testing.assert_allclose(s2, s1[:, 1::2, 1::2, :], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bci,bco", [(1, 8), (4, 4), (8, 16), (16, 2)])
def test_kpu_tilings_equivalent(bci, bco):
    k1, k2 = jax.random.split(jax.random.key(3))
    x = _rand(k1, (1, 6, 6, 16))
    w = _rand(k2, (3, 3, 16, 16))
    got = kpu_conv(x, w, bci=bci, bco=bco)
    np.testing.assert_allclose(got, kpu_conv_ref(x, w), rtol=1e-4, atol=1e-4)


def test_kpu_first_layer_mobilenet_shape():
    """conv1 of MobileNet: 3->32, stride 2 — the paper's entry layer."""
    k1, k2 = jax.random.split(jax.random.key(4))
    x = _rand(k1, (1, 16, 16, 3))
    w = _rand(k2, (3, 3, 3, 32))
    got = kpu_conv(x, w, stride=2)
    assert got.shape == (1, 8, 8, 32)
    np.testing.assert_allclose(got, kpu_conv_ref(x, w, stride=2),
                               rtol=1e-4, atol=1e-4)
