"""Fused SSD chunk kernel vs the (already recurrence-validated) oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ssd_chunk import ssd_chunk, ssd_chunk_ref


def _inputs(key, b, l, h, p, g, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, l, g, n)) * 0.3
    cc = jax.random.normal(ks[4], (b, l, g, n)) * 0.3
    return x, dt, a, bb, cc


@given(l=st.sampled_from([32, 64]), chunk=st.sampled_from([8, 16, 32]),
       h=st.sampled_from([4, 8]), g=st.sampled_from([1, 2]))
@settings(max_examples=10, deadline=None)
def test_ssd_kernel_matches_ref(l, chunk, h, g):
    if g > h:
        g = h
    x, dt, a, bb, cc = _inputs(jax.random.key(0), 2, l, h, 8, g, 16)
    y_k, s_k = ssd_chunk(x, dt, a, bb, cc, chunk=chunk, head_block=4)
    bh = jnp.repeat(bb, h // g, axis=2)
    ch = jnp.repeat(cc, h // g, axis=2)
    y_r, s_r = ssd_chunk_ref(x, dt, a, bh, ch, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("head_block", [2, 4, 8])
def test_ssd_kernel_head_tilings_equivalent(head_block):
    x, dt, a, bb, cc = _inputs(jax.random.key(1), 1, 32, 8, 8, 2, 16)
    base, s0 = ssd_chunk(x, dt, a, bb, cc, chunk=16, head_block=8)
    got, s1 = ssd_chunk(x, dt, a, bb, cc, chunk=16, head_block=head_block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)


def test_ssd_kernel_mamba2_shape():
    """mamba2-780m-like head geometry (scaled down in L)."""
    x, dt, a, bb, cc = _inputs(jax.random.key(2), 1, 64, 8, 64, 1, 32)
    y, s = ssd_chunk(x, dt, a, bb, cc, chunk=32, head_block=8)
    assert y.shape == (1, 64, 8, 64)
    assert s.shape == (1, 8, 64, 32)
    assert bool(jnp.all(jnp.isfinite(y)))
