"""Multi-tenant fleet scheduler (fleet/scheduler.py).

Acceptance surface: >= 2 families served concurrently on one
heterogeneous chip budget, zero stalls at <= each tenant's BestRate,
per-tenant results identical to standalone runs (tenants share the
clock, never chips), and an execute=True run whose outputs match the
plain executor.
"""
from fractions import Fraction as F

import jax
import numpy as np
import pytest

from repro.fleet import (
    Chip,
    FleetError,
    FleetScheduler,
    Tenant,
    TenantWorkload,
    chip_pool,
    plan_pool,
)
from repro.models.registry import get_cnn_api
from repro.serving.cnn_stream import best_rate_frames

TENANTS = (
    Tenant("alpha", "resnet18", F(1, 2), input_hw=(32, 32), num_classes=10),
    Tenant("beta", "mobilenet_v2", F(1, 2), input_hw=(32, 32), num_classes=10),
)
CHIPS = (Chip("big0", bram36=4096),) + chip_pool(4)


@pytest.fixture(scope="module")
def pool():
    return plan_pool(TENANTS, CHIPS, s_options=(1, 2), try_replicate=True)


def _workloads(pool, frac=F(1)):
    """Per-tenant loads at ``frac`` x that tenant's own BestRate."""
    out = []
    for name, frames in (("alpha", 24), ("beta", 16)):
        br = best_rate_frames(pool.candidate_for(name).plan)
        out.append(TenantWorkload(name, frames, arrival_rate=frac * br))
    return out


def test_two_families_zero_stalls_at_best_rate(pool):
    sched = FleetScheduler(pool, execute=False)
    rep = sched.serve(_workloads(pool, frac=F(1)))
    assert set(rep.reports) == {"alpha", "beta"}
    assert rep.all_stall_free
    assert rep.all_within_bounds
    for r in rep.reports.values():
        assert r.completed == r.frames
        assert r.admitted_rate == r.arrival_rate  # <= BestRate: no throttle


def test_fleet_matches_standalone(pool):
    """Tenants share the clock but not chips, so the fleet run of each
    tenant is event-for-event its standalone run."""
    sched = FleetScheduler(pool, execute=False)
    workloads = _workloads(pool, frac=F(1, 2))
    fleet = sched.serve(workloads)
    for w in workloads:
        solo = sched._engine(w).run(arrival_rate=w.arrival_rate)
        got = fleet.reports[w.tenant]
        assert got.makespan_ticks == solo.makespan_ticks
        assert got.latency_ticks == solo.latency_ticks
        assert got.service_latency_ticks == solo.service_latency_ticks
        assert [s.busy_cycles for s in got.stages] == [
            s.busy_cycles for s in solo.stages
        ]


def test_chip_occupancy_over_fleet_makespan(pool):
    sched = FleetScheduler(pool, execute=False)
    rep = sched.serve(_workloads(pool))
    assert set(rep.chip_occupancy) == {c.name for c in CHIPS}
    for name in pool.spare_chips:
        assert rep.chip_occupancy[name] == 0.0
    for a in pool.assignments:
        busy = rep.reports[a.tenant].stages[a.stage].busy_cycles
        want = float(busy / rep.makespan_cycles)
        assert rep.chip_occupancy[a.chip] == pytest.approx(want)
        assert 0 < rep.chip_occupancy[a.chip] <= 1


def test_execute_outputs_match_plain_apply():
    tenants = (
        Tenant("a", "resnet18", F(1, 4), input_hw=(16, 16), num_classes=4),
        Tenant("b", "mobilenet_v1", F(1, 4), input_hw=(16, 16),
               num_classes=4),
    )
    pool = plan_pool(tenants, (Chip("big0", bram36=4096),) + chip_pool(3),
                     s_options=(1, 2))
    sched = FleetScheduler(pool, execute=True)
    sched.init_params("a", jax.random.PRNGKey(0))
    sched.init_params("b", jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    fa = rng.standard_normal((5, 16, 16, 3)).astype(np.float32)
    fb = rng.standard_normal((3, 16, 16, 3)).astype(np.float32)
    rep = sched.serve([TenantWorkload("a", fa), TenantWorkload("b", fb)])
    assert rep.all_stall_free
    for name, frames, fam in (("a", fa, "resnet18"), ("b", fb,
                                                      "mobilenet_v1")):
        api = get_cnn_api(fam)
        ref = np.asarray(
            api.apply(sched.params[name], frames,
                      pool.candidate_for(name).cfg))
        np.testing.assert_allclose(rep.outputs[name], ref, rtol=1e-4,
                                   atol=1e-4)


def test_scheduler_validation_errors(pool):
    sched = FleetScheduler(pool, execute=False)
    with pytest.raises(FleetError, match="no workloads"):
        sched.serve([])
    with pytest.raises(FleetError, match="unpooled tenant"):
        sched.serve([TenantWorkload("nobody", 4)])
    with pytest.raises(FleetError, match="duplicate workload"):
        sched.serve([TenantWorkload("alpha", 4), TenantWorkload("alpha", 4)])
    with pytest.raises(FleetError, match="no params"):
        FleetScheduler(pool, execute=True).serve(
            [TenantWorkload("alpha", 4)])
