"""Chip-pool planner (fleet/pool.py).

The pinned scenario (shared with ``benchmarks/table7_fleet.py``): two
rate-targeted tenants packed onto a heterogeneous budget, one stage per
chip, every candidate priced by the analytic resource model.
"""
from fractions import Fraction as F

import pytest

from repro.fleet.pool import (
    Chip,
    PoolError,
    Tenant,
    chip_pool,
    enumerate_candidates,
    plan_pool,
)

TENANTS = (
    Tenant("alpha", "resnet18", F(1, 2), input_hw=(32, 32), num_classes=10),
    Tenant("beta", "mobilenet_v2", F(1, 2), input_hw=(32, 32), num_classes=10),
)
CHIPS = (Chip("big0", bram36=4096),) + chip_pool(4)


@pytest.fixture(scope="module")
def pool():
    return plan_pool(TENANTS, CHIPS, s_options=(1, 2), try_replicate=True)


def test_every_tenant_served_at_target_rate(pool):
    assert set(pool.chosen) == {"alpha", "beta"}
    for t in TENANTS:
        cand = pool.candidate_for(t.name)
        # the plan was run at the tenant's target rate; scheme 'ours'
        # satisfies Eq. 9 on every node by construction
        assert cand.plan.input_rate == t.input_rate
        assert not cand.plan.infeasible_nodes


def test_one_stage_per_chip_and_within_budget(pool):
    chips = {c.name: c for c in CHIPS}
    used = [a.chip for a in pool.assignments]
    assert len(used) == len(set(used))  # exclusive chips
    for a in pool.assignments:
        cand = pool.candidate_for(a.tenant)
        assert chips[a.chip].fits(cand.stage_costs[a.stage])
        assert 0 < a.dsp_frac <= 1 and 0 <= a.bram_frac <= 1
    # every stage of every chosen candidate landed somewhere
    placed = {(a.tenant, a.stage) for a in pool.assignments}
    want = {(n, s) for n, c in pool.chosen.items() for s in range(c.n_stages)}
    assert placed == want
    assert len(pool.spare_chips) == len(CHIPS) - len(pool.assignments)


def test_objective_minimizes_arithmetic(pool):
    """The chosen combo's total mults is minimal over all feasible
    per-tenant candidates (exhaustive check on this small instance)."""
    per_tenant_min = 0
    for t in TENANTS:
        cands = enumerate_candidates(t, CHIPS, s_options=(1, 2))
        per_tenant_min += min(c.total_mults for c in cands)
    # the pool is big enough here that per-tenant minima are packable
    assert pool.total_mults == per_tenant_min


def test_utilization_and_fair_share_report(pool):
    util = pool.utilization()
    assert set(util) == {c.name for c in CHIPS}
    for name in pool.spare_chips:
        assert util[name]["dsp"] == 0.0
    share = pool.fair_share()
    assert sum(share.values()) == len(CHIPS)
    assert all(v >= 1 for v in share.values())
    # ResNet-18 dominates the arithmetic, so gets the lion's share
    assert share["alpha"] > share["beta"]


def test_heterogeneity_matters():
    """The ResNet tail stage over-fills a stock chip's BRAM — without
    the big-memory chip the pool is infeasible at S<=2."""
    with pytest.raises(PoolError, match="alpha"):
        plan_pool(TENANTS, chip_pool(5), s_options=(1, 2))


def test_pool_validation_errors():
    with pytest.raises(PoolError, match="duplicate tenant"):
        plan_pool((TENANTS[0], TENANTS[0]), CHIPS, s_options=(1,))
    with pytest.raises(PoolError, match="no chips"):
        plan_pool(TENANTS, (), s_options=(1,))
    with pytest.raises(PoolError, match="no tenants"):
        plan_pool((), CHIPS, s_options=(1,))
    # two tenants, one chip: candidates exist but nothing packs
    with pytest.raises(PoolError, match="packs onto"):
        plan_pool(TENANTS, (Chip("big0", bram36=4096),), s_options=(1,))
    with pytest.raises(PoolError, match="max_combos"):
        plan_pool(TENANTS, CHIPS, s_options=(1, 2), max_combos=1)
