"""Multi-CLP bottleneck replication (core/replicate.py).

Property surface, per the fleet subsystem's acceptance bar:

* rate algebra — for every registry family and R in {2, 3}, the
  replicated plan's discrete-event run (``simulate_graph``) is
  stall-free with every FIFO within its analytic bound, and the merge
  restores exactly the unreplicated output rate;
* executor — split/merge round-trips are bit-exact: fp32 allclose and
  int8 bit-exact against the *unreplicated* ``apply_graph``, including
  2D (dense) replication and staged execution;
* planning — the replication DSE strictly improves the ResNet-18
  S=3 min-bottleneck balance at equal total arithmetic (the pinned
  ``benchmarks/table7_fleet.py`` row), and the baseline always competes
  (``best_replication`` is never worse than ``plan_graph``);
* validation — bad node names, kinds, and R values fail loudly.
"""
from fractions import Fraction as F

import jax
import numpy as np
import pytest

from repro.core.graph import GraphError, plan_graph
from repro.core.replicate import (
    apply_replications,
    best_replication,
    lane_multiplicity,
    replicable_nodes,
    replicate_node,
    replicate_params,
    select_bottleneck,
)
from repro.core.schedule import simulate_graph
from repro.models import cnn
from repro.models.registry import cnn_families, get_cnn_api

RATE = F(1, 2)
HW = 16


def _family_graph(family, hw=HW, num_classes=4):
    api = get_cnn_api(family)
    cfg = api.make_config(input_hw=(hw, hw), num_classes=num_classes)
    return api, cfg, cfg.graph()


# ---------------------------------------------------------------------------
# rate algebra: replicated plans keep continuous flow, lanes carry rate/R
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", cnn_families())
@pytest.mark.parametrize("r", (2, 3))
def test_replicated_sim_stall_free_within_bounds(family, r):
    _, _, graph = _family_graph(family)
    plan = plan_graph(graph, RATE, replicate=r)
    rep = plan.replications[0]
    res = simulate_graph(plan, n_pixels=64)
    assert res.stall_free, res.stalled_nodes
    assert res.within_bounds
    # the merge restores the unreplicated output rate; lanes carry 1/R
    base = plan_graph(graph, RATE)
    assert plan.timing[rep.merge].q_out == base.timing[rep.node].q_out
    for lane in rep.lanes:
        assert plan.demands[lane] == base.demands[rep.node] / r
        assert lane_multiplicity(plan, lane) == r
    assert lane_multiplicity(plan, rep.merge) == 1


@pytest.mark.parametrize("r", (2, 3))
def test_lanes_identical_and_sized_for_dealt_rate(r):
    """All R lanes get the same impl, chosen for demand/R (Eq. 9 on the
    lane); sums may differ from the base by divisor granularity — at
    even splits (R=2 here) arithmetic is exactly preserved."""
    _, _, graph = _family_graph("resnet18")
    base = plan_graph(graph, RATE)
    plan = plan_graph(graph, RATE, replicate=r)
    rep = plan.replications[0]
    impls = [plan.impls[lane] for lane in rep.lanes]
    assert all((i.j, i.h, i.mults) == (impls[0].j, impls[0].h,
                                       impls[0].mults) for i in impls)
    for i in impls:
        assert i.capacity >= base.demands[rep.node] / r  # Eq. 9 per lane
    if r == 2:
        assert sum(i.mults for i in impls) == base.impls[rep.node].mults
    # split/merge are wiring: no multipliers
    assert plan.impls[rep.split].mults == 0
    assert plan.impls[rep.merge].mults == 0


# ---------------------------------------------------------------------------
# executor: split/merge round-trip vs the unreplicated apply_graph
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ("mobilenet_v2", "resnet18"))
@pytest.mark.parametrize("r", (2, 3))
def test_replicated_apply_matches_unreplicated(family, r):
    api, cfg, graph = _family_graph(family)
    params = api.init(cfg, jax.random.PRNGKey(0))
    rg, reps = apply_replications(graph, r, input_rate=RATE)
    rparams = replicate_params(params, reps)
    x = np.random.default_rng(1).standard_normal((5, HW, HW, 3))
    x = x.astype(np.float32)
    ref = np.asarray(cnn.apply_graph(params, x, graph))
    got = np.asarray(cnn.apply_graph(rparams, x, rg))
    np.testing.assert_array_equal(got, ref)  # bit-exact: same math per lane


def test_replicated_apply_int8_bit_exact():
    api, cfg, graph = _family_graph("resnet18")
    params = api.init(cfg, jax.random.PRNGKey(0))
    q_params, scales = api.quantize(params)
    rg, reps = apply_replications(graph, (select_bottleneck(
        plan_graph(graph, RATE)), 2), input_rate=RATE)
    x = np.random.default_rng(2).standard_normal((4, HW, HW, 3))
    x = x.astype(np.float32)
    ref = np.asarray(cnn.apply_int8(q_params, scales, x, graph))
    got = np.asarray(cnn.apply_int8(
        replicate_params(q_params, reps), replicate_params(scales, reps),
        x, rg))
    np.testing.assert_array_equal(got, ref)


def test_dense_replication_2d_round_trip():
    """Replicating the classifier exercises the 2D deal/merge path."""
    api, cfg, graph = _family_graph("mobilenet_v1")
    params = api.init(cfg, jax.random.PRNGKey(3))
    dense = [n for n in replicable_nodes(graph)
             if graph.spec(n).kind == "dense"][-1]
    rg, reps = apply_replications(graph, (dense, 3), input_rate=RATE)
    x = np.random.default_rng(4).standard_normal((7, HW, HW, 3))
    x = x.astype(np.float32)
    ref = np.asarray(cnn.apply_graph(params, x, graph))
    got = np.asarray(cnn.apply_graph(replicate_params(params, reps), x, rg))
    np.testing.assert_array_equal(got, ref)


def test_staged_apply_over_replicated_graph():
    api, cfg, graph = _family_graph("resnet18")
    params = api.init(cfg, jax.random.PRNGKey(5))
    plan = plan_graph(graph, RATE, n_stages=3, replicate=2)
    rparams = replicate_params(params, plan.replications)
    x = np.random.default_rng(6).standard_normal((3, HW, HW, 3))
    x = x.astype(np.float32)
    ref = np.asarray(cnn.apply_graph(params, x, graph))
    got = np.asarray(
        cnn.apply_staged(rparams, x, plan.graph, partition=plan))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# planning: the replication DSE and its strict-improvement pin
# ---------------------------------------------------------------------------

def test_best_replication_strictly_improves_resnet18_s3():
    """The table7 pin: bottleneck 18944 -> 18624 at equal arithmetic."""
    api = get_cnn_api("resnet18")
    graph = api.graph(api.make_config())
    base = plan_graph(graph, F(3), n_stages=3)
    rep = best_replication(graph, F(3), n_stages=3)
    assert max(base.stage_mults()) == 18944
    assert rep.replications, "replication DSE kept the baseline"
    assert max(rep.stage_mults()) == 18624
    assert rep.total_mults == base.total_mults == 54736


def test_best_replication_never_worse_than_baseline():
    _, _, graph = _family_graph("mobilenet_v1")
    base = plan_graph(graph, RATE, n_stages=2)
    rep = best_replication(graph, RATE, n_stages=2)
    assert max(rep.stage_mults()) <= max(base.stage_mults())


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_replicate_validation_errors():
    _, _, graph = _family_graph("resnet18")
    hot = replicable_nodes(graph)[0]
    with pytest.raises(GraphError, match="R must be >= 2"):
        replicate_node(graph, hot, 1)
    with pytest.raises(GraphError, match="unknown node"):
        replicate_node(graph, "nope", 2)
    pool = next(n for n in graph.topo_order()
                if graph.spec(n).kind not in ("conv", "dwconv", "pointwise",
                                              "dense"))
    with pytest.raises(GraphError, match="not replicable"):
        replicate_node(graph, pool, 2)
    with pytest.raises(GraphError, match="expected node/R spec"):
        apply_replications(graph, True)
