"""Drift auditor + engine integration (obs/audit.py).

The acceptance surface: with tracing ON the auditor reproduces every
run-level verdict of the engine's ``ServeSummary`` from the trace
alone; with tracing OFF the run is event-identical to an untraced one;
and a deliberately tampered service time is flagged with a localized
first-drift window.
"""
from fractions import Fraction as F

import pytest

from repro.core.graph import plan_graph
from repro.models.registry import get_cnn_api
from repro.obs import AuditError, Tracer, audit, audit_fleet
from repro.serving import PlanLadder, ServeConfig, ShedPolicy, adversarial
from repro.serving.cnn_stream import CNNStreamEngine, best_rate_frames

FAMILIES = ("mobilenet_v2", "resnet18")


def _run(family, n_stages, *, arrival_frac=F(1), n_frames=24, microbatch=4,
         rate=F(3), trace=True, overload=None, scenario=None):
    api = get_cnn_api(family)
    cfg = api.make_config(input_hw=(32, 32), num_classes=10)
    graph = cfg.graph()
    plan = plan_graph(graph, rate, n_stages=n_stages)
    arrival = (scenario if scenario is not None
               else arrival_frac * best_rate_frames(plan))
    eng = CNNStreamEngine(graph, None, plan, ServeConfig(
        microbatch=microbatch, execute=False, arrival=arrival,
        trace=trace, overload=overload))
    for _ in range(n_frames):
        eng.submit(None)
    return eng.run(), graph, plan


# ---------------------------------------------------------------------------
# row reproduction + verdict agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("arrival_frac", (F(1, 2), F(1), F(2)))
def test_audit_reproduces_summary_verdicts(family, arrival_frac):
    rep, _, _ = _run(family, 2, arrival_frac=arrival_frac)
    ar = audit(rep.trace)
    summary = rep.summary()
    assert ar.matches(summary)
    # bottleneck occupancy recomputed from spans is float-equal (both
    # sides divide exact Fractions before the one float conversion)
    assert (ar.rows[ar.bottleneck_row].measured_occupancy
            == summary.bottleneck_occupancy)
    assert [r.max_queue for r in ar.rows] == list(summary.max_queue)
    assert ar.clean


def test_audit_under_shed_policy_matches():
    rep, _, _ = _run(
        "resnet18", 2, arrival_frac=F(2), n_frames=48,
        overload=ShedPolicy(deadline_ticks=F(24)))
    ar = audit(rep.trace)
    assert ar.shed > 0
    assert ar.matches(rep.summary())


def test_audit_localizes_backpressure_stall():
    """The table8 adversarial overload: arrivals just above BestRate
    back-pressure the upstream stage; the auditor names the exact
    first stall tick from the blocked spans."""
    api = get_cnn_api("resnet18")
    graph = api.graph(api.make_config())
    ladder = PlanLadder.build(
        graph, F(5, 2), n_stages=2, rate_factors=(1, 2),
        try_replicate=True)
    plan = ladder.rungs[0].plan
    eng = CNNStreamEngine(graph, None, plan, ServeConfig(
        microbatch=4, execute=False,
        arrival=adversarial(best_rate_frames(plan)), trace=True))
    for _ in range(768):
        eng.submit(None)
    rep = eng.run()
    summary = rep.summary()
    assert not summary.stall_free and summary.overloaded
    ar = audit(rep.trace)
    assert ar.matches(summary)
    assert ar.first_stall is not None
    assert "stalled at tick" in ar.localization()
    # the trace's summed blocked time equals the engine's stall ticks
    total = sum((s.dur_ticks for s in ar.stalls), F(0))
    assert float(total) == pytest.approx(summary.stall_ticks)


def test_audit_needs_metadata_and_pid():
    with pytest.raises(AuditError):
        audit(Tracer())
    rep, _, _ = _run("resnet18", 1)
    with pytest.raises(AuditError):
        audit(rep.trace, pid="nope")


# ---------------------------------------------------------------------------
# zero overhead when off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_tracing_off_is_event_identical(family):
    on, _, _ = _run(family, 3, arrival_frac=F(2), n_frames=48)
    off, _, _ = _run(family, 3, arrival_frac=F(2), n_frames=48, trace=None)
    assert off.trace is None and off.metrics is None
    assert off.summary().metrics is None
    assert off.summary().line() == on.summary().line()
    assert off.summary().to_rows() == on.summary().to_rows()
    # the full event timeline, not just the rendering
    assert [(s.stage, s.rung, s.busy_cycles, s.stall_cycles)
            for s in off.stages] == [
        (s.stage, s.rung, s.busy_cycles, s.stall_cycles)
        for s in on.stages]


# ---------------------------------------------------------------------------
# tamper detection
# ---------------------------------------------------------------------------

def _tamper_last_stage_end(trace, delta_ticks=1):
    data = trace.to_chrome()
    stage_e = [ev for ev in data["traceEvents"]
               if ev.get("name") == "stage" and ev.get("ph") == "E"]
    last = max(stage_e, key=lambda ev: F(ev["args"]["__t__"]))
    t = F(last["args"]["__t__"]) + delta_ticks
    last["args"]["__t__"] = f"{t.numerator}/{t.denominator}"
    last["ts"] += float(delta_ticks)
    return Tracer.from_chrome(data)


def test_audit_flags_tampered_service_time():
    rep, _, _ = _run("resnet18", 3, n_frames=48)
    assert audit(rep.trace).clean
    ar = audit(_tamper_last_stage_end(rep.trace))
    assert not ar.clean
    drift = ar.first_drift
    assert drift is not None
    assert "service" in drift.reason or "overlap" in drift.reason
    assert "drifted at tick" in ar.localization()


def test_audit_survives_chrome_roundtrip():
    rep, _, _ = _run("mobilenet_v2", 2, arrival_frac=F(2), n_frames=48)
    ar = audit(rep.trace)
    ar_rt = audit(Tracer.from_chrome(rep.trace.dumps()))
    assert ar_rt.verdict_line() == ar.verdict_line()
    assert ar_rt.matches(rep.summary())


# ---------------------------------------------------------------------------
# fleet: shared tracer, per-tenant timelines
# ---------------------------------------------------------------------------

def test_fleet_shared_tracer_audits_every_tenant():
    from repro.fleet import (
        Chip, FleetScheduler, Tenant, TenantWorkload, chip_pool, plan_pool)

    tenants = (
        Tenant("alpha", "resnet18", F(1, 2), input_hw=(32, 32),
               num_classes=10),
        Tenant("beta", "mobilenet_v2", F(1, 2), input_hw=(32, 32),
               num_classes=10),
    )
    pp = plan_pool(tenants, (Chip("big0", bram36=4096),) + chip_pool(4),
                   s_options=(1, 2), try_replicate=True)
    sched = FleetScheduler(pp, config=ServeConfig(execute=False, trace=True))
    rep = sched.serve([
        TenantWorkload("alpha", 24, arrival_rate=F(1)),
        TenantWorkload("beta", 16, arrival_rate=F(1, 2))])
    assert rep.trace is sched.tracer
    assert sorted(rep.trace.meta) == ["alpha", "beta"]
    audits = audit_fleet(rep.trace)
    for name, ar in audits.items():
        assert ar.matches(rep.reports[name].summary(label=name))
    # stage spans carry the pool's chip assignment
    chips = {s.arg("chip") for s in rep.trace.spans("stage", pid="alpha")}
    assert chips == {a.chip for a in pp.assignments if a.tenant == "alpha"}
    # tick model: no host-clock spans, so no measured fps
    assert rep.tenant_wall_s == {}
