"""Span tracer invariants (obs/trace.py).

The tracer's contract: every begin has an end (FIFO-paired per
(pid, tid, name) track), exact rational timestamps survive the Chrome
trace-event JSON round trip, and the per-frame lifecycle view over a
real engine run shows exactly one stage span per pipeline stage the
frame crossed.
"""
from fractions import Fraction as F

import pytest

from repro.core.graph import plan_graph
from repro.models.registry import get_cnn_api
from repro.obs import TraceError, Tracer, resolve_tracer
from repro.serving import ServeConfig
from repro.serving.cnn_stream import CNNStreamEngine, best_rate_frames

FAMILIES = ("mobilenet_v2", "resnet18")


def _traced_run(family, n_stages, *, n_frames=24, microbatch=4,
                arrival=None, rate=F(3)):
    api = get_cnn_api(family)
    cfg = api.make_config(input_hw=(32, 32), num_classes=10)
    graph = cfg.graph()
    plan = plan_graph(graph, rate, n_stages=n_stages)
    arrival = best_rate_frames(plan) if arrival is None else arrival
    eng = CNNStreamEngine(graph, None, plan, ServeConfig(
        microbatch=microbatch, execute=False, arrival=arrival, trace=True))
    for _ in range(n_frames):
        eng.submit(None)
    return eng.run(), plan


# ---------------------------------------------------------------------------
# emission + query primitives
# ---------------------------------------------------------------------------

def test_span_pairing_is_fifo_per_track():
    tr = Tracer()
    tr.begin("work", F(0), pid="p", tid="t", bid=0)
    tr.begin("work", F(1), pid="p", tid="t", bid=1)
    tr.end("work", F(2), pid="p", tid="t")
    tr.end("work", F(5), pid="p", tid="t")
    spans = tr.spans("work")
    assert [(s.start, s.end) for s in spans] == [(F(0), F(2)), (F(1), F(5))]
    assert [s.arg("bid") for s in spans] == [0, 1]


def test_unbalanced_spans_raise():
    tr = Tracer()
    tr.begin("work", F(0))
    with pytest.raises(TraceError):
        tr.spans()
    with pytest.raises(TraceError):
        tr.check_balanced()


def test_resolve_tracer_contract():
    assert resolve_tracer(None) is None
    assert resolve_tracer(False) is None
    fresh = resolve_tracer(True)
    assert isinstance(fresh, Tracer)
    shared = Tracer()
    assert resolve_tracer(shared) is shared
    with pytest.raises(TraceError):
        resolve_tracer("yes")


def test_counter_series_keeps_emit_order():
    tr = Tracer()
    tr.counter("depth", 2, F(3), pid="p", tid="t")
    tr.counter("depth", 1, F(1), pid="p", tid="t")
    assert tr.counter_series("depth", pid="p", tid="t") == [
        (F(3), 2.0), (F(1), 1.0)]


# ---------------------------------------------------------------------------
# Chrome trace-event JSON round trip
# ---------------------------------------------------------------------------

def test_chrome_roundtrip_is_exact():
    tr = Tracer()
    tr.metadata("p", {"slot_cycles": "7/3"})
    tr.span("stage", F(1, 3), F(7, 3), pid="p", tid="stage0",
            frames=4, rids=(0, 1, 2, 3), ratio=F(5, 2))
    tr.instant("done", F(7, 3), pid="p", rid=3)
    tr.counter("queue_depth", 2, F(2), pid="p", tid="stage0")
    back = Tracer.from_chrome(tr.to_chrome())
    assert back.meta == tr.meta
    assert len(back.events) == len(tr.events)
    for a, b in zip(tr.events, back.events):
        assert (a.name, a.ph, a.pid, a.tid, a.clock) == (
            b.name, b.ph, b.pid, b.tid, b.clock)
        assert a.t == b.t  # exact Fraction, not float ts
        assert a.value == b.value
    sp = back.spans("stage")[0]
    assert sp.duration == F(2)
    assert sp.arg("ratio") == F(5, 2)  # Fractions survive encoding
    assert list(sp.arg("rids")) == [0, 1, 2, 3]


def test_dumps_write_parse(tmp_path):
    tr = Tracer()
    tr.span("s", F(0), F(1), pid="p", tid="t")
    path = tmp_path / "trace.json"
    tr.write(str(path))
    back = Tracer.from_chrome(path.read_text())
    assert len(back.spans()) == 1


# ---------------------------------------------------------------------------
# engine-emitted traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("n_stages", (1, 2, 3))
def test_engine_trace_is_balanced(family, n_stages):
    rep, _ = _traced_run(family, n_stages)
    rep.trace.check_balanced()
    spans = rep.trace.spans("stage", clock="ticks")
    assert spans, "engine emitted no stage spans"
    assert all(s.duration > 0 for s in spans)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("n_stages", (1, 2, 3))
def test_frame_span_count_equals_stages_crossed(family, n_stages):
    """Every served frame's lifecycle shows exactly one stage span per
    pipeline stage (single segment: no plan switch mid-run)."""
    rep, _ = _traced_run(family, n_stages)
    tr = rep.trace
    n_frames = len(tr.select("submit", ph="i"))
    assert n_frames == 24
    for rid in range(n_frames):
        spans = tr.frame_spans(rid)
        assert len(spans) == n_stages
        assert sorted(s.tid for s in spans) == [
            f"stage{s}" for s in range(n_stages)]
        # lifecycle ordering: admit <= first stage start < done
        instants = {e.name: e.t for e in tr.frame_instants(rid)}
        assert instants["admit"] <= spans[0].start
        assert instants["done"] >= max(s.end for s in spans)


def test_stage_span_service_is_frames_times_utilization():
    """The deterministic tick model's sharpest invariant: a batch of n
    frames occupies stage s for exactly n * utilization_s ticks."""
    rep, plan = _traced_run("resnet18", 2)
    tr = rep.trace
    meta = tr.meta["engine"]
    utils = [F(u) for u in meta["rungs"][0]["utilization"]]
    for sp in tr.spans("stage", clock="ticks"):
        s = int(sp.tid[len("stage"):])
        assert sp.duration == sp.arg("frames") * utils[s]
