"""Metrics registry unit tests (obs/metrics.py)."""
from fractions import Fraction as F

import pytest

from repro.obs import MetricsError, MetricsRegistry, metric_key


def test_metric_key_sorts_labels():
    assert metric_key("q", {"stage": 1, "edge": "a->b"}) == (
        "q{edge=a->b,stage=1}")
    assert metric_key("q", {}) == "q"


def test_counter_is_exact_and_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("busy_ticks", stage=0)
    c.inc(F(5, 3))
    c.inc(F(1, 3))
    assert reg.value("busy_ticks", stage=0) == F(2)  # exact, not float
    with pytest.raises(MetricsError):
        c.inc(-1)


def test_counter_identity_per_label_set():
    reg = MetricsRegistry()
    a = reg.counter("frames", tenant="alpha")
    b = reg.counter("frames", tenant="beta")
    assert a is reg.counter("frames", tenant="alpha")
    a.inc()
    assert b.get() == 0


def test_gauge_tracks_high_water():
    reg = MetricsRegistry()
    g = reg.gauge("queue_depth", stage=1)
    g.set(3)
    g.set(1)
    assert g.get() == 1
    assert g.max_value == 3
    snap = reg.snapshot()
    assert snap["queue_depth{stage=1}"] == 1
    assert snap["queue_depth{stage=1}:max"] == 3


def test_histogram_percentiles_are_nearest_rank():
    reg = MetricsRegistry()
    h = reg.histogram("latency_ticks")
    for v in range(1, 101):
        h.observe(float(v))
    # nearest-rank: matches ServeReport._pct exactly
    assert h.percentile(0.5) == 50.0
    assert h.percentile(0.99) == 99.0
    stats = h.get()
    assert stats["count"] == 100
    assert stats["min"] == 1.0 and stats["max"] == 100.0
    assert stats["p50"] == 50.0 and stats["p99"] == 99.0


def test_kind_clash_raises():
    reg = MetricsRegistry()
    reg.counter("frames")
    with pytest.raises(MetricsError):
        reg.gauge("frames")


def test_snapshot_is_sorted_and_plain():
    reg = MetricsRegistry()
    reg.counter("b").inc()
    reg.counter("a").inc(2)
    snap = reg.snapshot()
    assert list(snap)[:2] == sorted(list(snap)[:2])
    assert "a" in reg and "zzz" not in reg
    assert reg.value("nope") is None
