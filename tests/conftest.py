"""Test-session bootstrap.

1. Ensures ``src`` is importable even when pytest is launched without
   ``PYTHONPATH=src`` and the package is not pip-installed (the
   ``pythonpath`` ini option in pyproject.toml covers modern pytest;
   this covers direct ``python -m pytest`` from odd CWDs).

2. Provides a minimal **hypothesis shim** when the real library is
   absent.  The seed image is a bare interpreter; rather than skipping
   every property test we register a deterministic sampler that runs
   each ``@given`` test over a fixed number of pseudo-random examples
   (seeded per test, so failures are reproducible).  When the real
   ``hypothesis`` is installed it is used untouched.
"""
from __future__ import annotations

import functools
import os
import random
import sys
from fractions import Fraction

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def _install_hypothesis_stub() -> None:
    import types

    _DEFAULT_EXAMPLES = int(os.environ.get("REPRO_STUB_EXAMPLES", "25"))

    class _Strategy:
        """A draw function wrapper: ``sample(rng) -> value``."""

        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    def sampled_from(elements):
        elems = list(elements)
        if not elems:
            raise ValueError("sampled_from requires a non-empty sequence")
        return _Strategy(lambda rng: rng.choice(elems))

    def integers(min_value=0, max_value=2 ** 16):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def fractions(min_value=Fraction(0), max_value=Fraction(1), **_kw):
        lo, hi = Fraction(min_value), Fraction(max_value)

        def draw(rng: random.Random) -> Fraction:
            # Denominators up to 64 cover the repo's rate sweeps (3/32 etc.)
            for _ in range(64):
                den = rng.choice([1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64])
                n_lo = -(-lo.numerator * den // lo.denominator)   # ceil
                n_hi = hi.numerator * den // hi.denominator       # floor
                if n_lo <= n_hi:
                    return Fraction(rng.randint(n_lo, n_hi), den)
            return lo

        return _Strategy(draw)

    def lists(element, min_size=0, max_size=10, **_kw):
        def draw(rng: random.Random):
            k = rng.randint(min_size, max_size)
            return [element.sample(rng) for _ in range(k)]

        return _Strategy(draw)

    def just(value):
        return _Strategy(lambda _rng: value)

    def one_of(*strategies):
        strats = list(strategies)
        return _Strategy(lambda rng: rng.choice(strats).sample(rng))

    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))

    import inspect

    def given(*arg_strats, **kw_strats):
        def decorate(fn):
            n_examples = getattr(fn, "_stub_max_examples", None)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            # Strategy-bound params: keywords by name, positionals from the
            # right (hypothesis semantics).  Whatever is left over must be
            # pytest fixtures and stays in the visible signature.
            remaining = [p for p in params if p.name not in kw_strats]
            if arg_strats:
                remaining = remaining[: -len(arg_strats)]

            @functools.wraps(fn)
            def wrapper(*fixture_args, **fixture_kwargs):
                count = n_examples or getattr(
                    wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES
                )
                rng = random.Random(f"repro-stub:{fn.__module__}.{fn.__qualname__}")
                for i in range(count):
                    args = tuple(s.sample(rng) for s in arg_strats)
                    kwargs = {k: s.sample(rng) for k, s in kw_strats.items()}
                    kwargs.update(fixture_kwargs)
                    try:
                        fn(*fixture_args, *args, **kwargs)
                    except Exception as e:  # annotate the failing example
                        raise AssertionError(
                            f"stub-hypothesis example #{i} failed: "
                            f"args={args!r} kwargs={kwargs!r}"
                        ) from e

            wrapper.hypothesis_stub = True
            del wrapper.__wrapped__  # keep pytest from introspecting fn's params
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper

        return decorate

    def settings(max_examples=None, **_kw):
        def decorate(fn):
            if max_examples is not None:
                # Cap stub runtime: the real library amortizes via shrinking
                # and example DBs; the stub just runs fewer samples.
                fn._stub_max_examples = min(max_examples, _DEFAULT_EXAMPLES)
            return fn

        return decorate

    def assume(condition) -> bool:
        if not condition:
            raise _Rejected()
        return True

    class _Rejected(Exception):
        pass

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.note = lambda *_a, **_k: None
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None,
                                            filter_too_much=None)
    hyp.__version__ = "0.0-repro-stub"
    hyp.is_repro_stub = True

    strat_mod = types.ModuleType("hypothesis.strategies")
    strat_mod.sampled_from = sampled_from
    strat_mod.integers = integers
    strat_mod.booleans = booleans
    strat_mod.floats = floats
    strat_mod.fractions = fractions
    strat_mod.lists = lists
    strat_mod.just = just
    strat_mod.one_of = one_of
    strat_mod.tuples = tuples
    hyp.strategies = strat_mod

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat_mod


try:  # pragma: no cover - exercised implicitly by every test import
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_stub()
