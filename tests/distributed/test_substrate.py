"""Substrate tests: optimizer, data, checkpointing, fault tolerance,
gradient compression, pipeline parallelism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (AdamWConfig, accumulate_grads,
                                    apply_updates, init as adam_init)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quad_loss(params, batch):
    del batch
    loss = sum(jnp.sum(jnp.square(p)) for p in jax.tree.leaves(params))
    return loss, {"ce": loss}


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.ones((4, 4)) * 3.0, "b": jnp.ones((4,))}
    state = adam_init(cfg, params)
    for _ in range(200):
        grads = jax.grad(lambda p: _quad_loss(p, None)[0])(params)
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(_quad_loss(params, None)[0]) < 1e-2


def test_adamw_bf16_moments_close_to_f32():
    k = jax.random.key(0)
    params = {"w": jax.random.normal(k, (8, 8))}
    grads = {"w": jax.random.normal(jax.random.key(1), (8, 8))}
    outs = {}
    for dt in ("float32", "bfloat16"):
        cfg = AdamWConfig(lr=1e-2, mu_dtype=dt, nu_dtype=dt, warmup_steps=0)
        st = adam_init(cfg, params)
        p = params
        for _ in range(5):
            p, st, _ = apply_updates(cfg, p, grads, st)
        outs[dt] = np.asarray(p["w"])
    np.testing.assert_allclose(outs["float32"], outs["bfloat16"],
                               rtol=0.05, atol=0.05)
    # and the bf16 state actually IS bf16 (the memory claim)
    cfg = AdamWConfig(mu_dtype="bfloat16", nu_dtype="bfloat16")
    st = adam_init(cfg, params)
    assert st.mu["w"].dtype == jnp.bfloat16
    assert st.nu["w"].dtype == jnp.bfloat16


def test_factored_second_moment_shapes():
    cfg = AdamWConfig(factored=True)
    params = {"w": jnp.ones((16, 32)), "b": jnp.ones((32,))}
    st = adam_init(cfg, params)
    r, c = st.nu["w"]
    assert r.shape == (16,) and c.shape == (32,)       # d^2 -> 2d state
    assert st.nu["b"].shape == (32,)                   # 1D stays dense
    grads = jax.tree.map(jnp.ones_like, params)
    p2, st2, _ = apply_updates(cfg, params, grads, st)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(p2))


def test_grad_accumulation_matches_full_batch():
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean(jnp.square(pred - batch["y"]))
        return l, {"ce": l}

    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    params = {"w": jax.random.normal(k1, (8, 4))}
    batch = {"x": jax.random.normal(k2, (16, 8)),
             "y": jax.random.normal(k3, (16, 4))}
    _, _, g_full = accumulate_grads(loss_fn, params, batch, 1)
    _, _, g_micro = accumulate_grads(loss_fn, params, batch, 4)
    np.testing.assert_allclose(np.asarray(g_full["w"]),
                               np.asarray(g_micro["w"]), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_resumable():
    from repro.data.pipeline import SyntheticLM
    a = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=7)
    b1 = next(a)
    next(a)                     # advance past batch 2
    st = a.state_dict()
    b3 = next(a)
    fresh = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=7)
    fresh.load_state_dict(st)
    np.testing.assert_array_equal(next(fresh)["tokens"], b3["tokens"])
    # shards are disjoint streams
    s0 = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=7, shard_id=0,
                     num_shards=2)
    s1 = SyntheticLM(vocab=64, seq_len=8, batch=2, seed=7, shard_id=1,
                     num_shards=2)
    assert not np.array_equal(next(s0)["tokens"], next(s1)["tokens"])
    assert b1["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_packed_file_roundtrip(tmp_path):
    from repro.data.pipeline import PackedFileDataset, write_packed_file
    toks = np.arange(9 * 10, dtype=np.int64) % 97
    path = str(tmp_path / "toks.bin")
    write_packed_file(path, toks, vocab=97)
    ds = PackedFileDataset(path=path, vocab=97, seq_len=8, batch=2)
    b = next(ds)
    assert b["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpointing + restart drill
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones((4,))}}
    for step in (1, 2, 3):
        ck.save(step, jax.tree.map(lambda x: x * step, tree),
                extra={"data": {"step": step}})
    ck.wait()
    assert ck.latest_step() == 3
    restored, extra = ck.restore(None, tree)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 3)
    assert extra["data"]["step"] == 3
    # gc kept only 2
    assert sorted(int(p.name.split("_")[1])
                  for p in tmp_path.glob("step_*")) == [2, 3]


def test_checkpoint_atomicity_partial_write(tmp_path):
    """A leftover .tmp dir (simulated crash) must not be visible."""
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(str(tmp_path))
    (tmp_path / "step_9.tmp").mkdir()
    tree = {"a": jnp.ones((2,))}
    ck.save(1, tree, blocking=True)
    assert ck.latest_step() == 1


def test_preemption_drill(tmp_path):
    """Simulated preemption: train 5 steps, 'crash', resume, and the
    resumed run reproduces the uninterrupted run exactly."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.data.pipeline import SyntheticLM

    def train(steps, resume_dir=None, crash_at=None):
        cfg = AdamWConfig(lr=1e-2, warmup_steps=0)
        params = {"w": jnp.ones((8, 8))}
        state = adam_init(cfg, params)
        data = SyntheticLM(vocab=64, seq_len=4, batch=2, seed=3)
        ck = Checkpointer(str(tmp_path / "drill"))
        start = 0
        if resume_dir:
            (params, state), extra = ck.restore(None, (params, state))
            data.load_state_dict(extra["data"])
            start = extra["step"]
        for step in range(start, steps):
            batch = next(data)
            x = jnp.asarray(batch["tokens"], jnp.float32)[:, :4] / 64.0
            grads = jax.grad(
                lambda p: jnp.mean(jnp.square(x @ p["w"][:4, :4])))(params)
            params, state, _ = apply_updates(cfg, params, grads, state)
            ck.save(step + 1, (params, state),
                    extra={"step": step + 1, "data": data.state_dict()},
                    blocking=True)
            if crash_at is not None and step + 1 == crash_at:
                return params       # simulate preemption
        return params

    train(10, crash_at=5)       # writes checkpoints, then 'crashes'
    p_resumed = train(10, resume_dir=True)
    p_straight = None
    import shutil
    shutil.rmtree(tmp_path / "drill")
    p_straight = train(10)
    np.testing.assert_allclose(np.asarray(p_resumed["w"]),
                               np.asarray(p_straight["w"]), rtol=1e-6)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_watchdog():
    from repro.distributed.fault_tolerance import StragglerWatchdog
    wd = StragglerWatchdog(threshold=1.5, patience=3)
    fired = False
    for step in range(20):
        t = 1.0 if step < 10 else 2.5      # node goes bad at step 10
        fired = wd.observe(step, t)
        if fired:
            break
    assert fired and step == 12            # 3 consecutive slow steps
    assert wd.flagged_steps == [10, 11, 12]


def test_watchdog_tolerates_single_hiccup():
    from repro.distributed.fault_tolerance import StragglerWatchdog
    wd = StragglerWatchdog(threshold=1.5, patience=3)
    for step in range(20):
        t = 3.0 if step == 7 else 1.0
        assert not wd.observe(step, t)


def test_elastic_remesh_and_reshard():
    from repro.distributed.fault_tolerance import (ElasticMesh,
                                                   viable_mesh_shape)
    assert viable_mesh_shape(256, 16) == (16, 16)
    assert viable_mesh_shape(240, 16) == (15, 16)      # lost a host of 16
    assert viable_mesh_shape(8, 16) is None
    em = ElasticMesh(model_degree=1)
    mesh = em.remesh(jax.devices())                    # degraded 1-dev mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": np.arange(8.0)}
    out = em.reshard(tree, {"w": NamedSharding(mesh, P())})
    np.testing.assert_allclose(np.asarray(out["w"]), tree["w"])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compressed_psum_error_feedback_converges():
    """Mean of int8-compressed psum across a 4-way axis tracks the true
    mean, and error feedback drives the bias to ~0 over steps."""
    from repro.distributed.collectives import compressed_psum

    grads = jax.random.normal(jax.random.key(0), (4, 64))  # 4 workers
    true_mean = jnp.mean(grads, axis=0)

    def worker(g, r):
        return compressed_psum(g, r, "w")

    run = jax.vmap(worker, axis_name="w")
    res = jnp.zeros_like(grads)
    acc = jnp.zeros_like(true_mean)
    steps = 30
    for _ in range(steps):
        mean, res = run(grads, res)
        acc = acc + mean[0] / steps
    # single-shot quantization error is ~1%, accumulated bias far less
    assert float(jnp.max(jnp.abs(mean[0] - true_mean))) < 0.05
    assert float(jnp.max(jnp.abs(acc - true_mean))) < 0.02


# ---------------------------------------------------------------------------
# pipeline parallelism (single-CPU host: a 1-stage 'stage' mesh exercises
# the full ring schedule — scan, ppermute, banking — degenerately; the
# genuine 4-stage overlap runs in examples/pipeline_demo.py's forced
# 4-device child)
# ---------------------------------------------------------------------------

def test_pipeline_forward_matches_unpipelined_stack():
    from jax.sharding import Mesh
    from repro.distributed.pipeline_parallel import (pipeline_forward,
                                                     plan_stages_for_layers,
                                                     stack_stage_params)

    layers, d, m, mb = 3, 4, 4, 2
    key = jax.random.key(0)
    kw, kx = jax.random.split(key)
    params = {"w": jax.random.normal(kw, (layers, d, d)) * 0.3}
    x_micro = jax.random.normal(kx, (m, mb, d))

    def block_fn(p, x):
        for i in range(p["w"].shape[0]):
            x = jnp.tanh(x @ p["w"][i])
        return x

    plan = plan_stages_for_layers([1.0] * layers, 1)
    stacked = stack_stage_params(params, plan)   # [S=1, L, d, d]
    mesh = Mesh(np.array(jax.devices()[:1]), ("stage",))
    out = pipeline_forward(block_fn, stacked, x_micro, mesh)
    ref = jax.vmap(lambda x: block_fn(params, x))(x_micro)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_utilization_math():
    from repro.distributed.pipeline_parallel import microbatch_utilization
    assert microbatch_utilization(1, 4) == pytest.approx(0.25)
    assert microbatch_utilization(16, 4) == pytest.approx(16 / 19)
    assert microbatch_utilization(64, 8) > 0.9


def test_stage_param_stacking_pads_identity():
    from repro.core.stage_partition import partition_min_bottleneck
    from repro.distributed.pipeline_parallel import stack_stage_params
    params = {"w": jnp.arange(5 * 3.0).reshape(5, 3)}
    plan = partition_min_bottleneck([1.0, 1.0, 1.0, 1.0, 4.0], 2)
    stacked = stack_stage_params(params, plan)
    assert stacked["w"].shape[0] == 2                  # stages
    # padded rows are zero (identity for residual blocks)
    sizes = [plan.boundaries[i + 1] - plan.boundaries[i] for i in range(2)]
    smax = max(sizes)
    for s, size in enumerate(sizes):
        if size < smax:
            np.testing.assert_allclose(
                np.asarray(stacked["w"][s, size:]), 0.0)
