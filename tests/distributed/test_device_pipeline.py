"""DevicePipeline: placement resolution, GPipe schedule correctness,
donation/double-buffering safety, the wall-clock report, and the
serving engine's execute="devices" path — all on the single-CPU host
(the fewer-devices-than-stages fallback; genuine multi-device overlap
is exercised by examples/pipeline_demo.py's forced 4-device child)."""

from fractions import Fraction as F

import jax
import numpy as np
import pytest

from repro.core.graph import GraphError, plan_graph
from repro.core.stage_partition import round_robin_placement
from repro.distributed.device_pipeline import (
    DevicePipeline,
    DevicePipelineError,
    device_placement_rows,
)
from repro.models import cnn
from repro.models.registry import get_cnn_api


@pytest.fixture(scope="module")
def resnet():
    api = get_cnn_api("resnet18")
    cfg = api.make_config(input_hw=(32, 32), num_classes=10)
    params = api.init(cfg, jax.random.PRNGKey(0))
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3)),
        dtype=np.float32,
    )
    return api, cfg, params, x


# ---------------------------------------------------------------------------
# placement resolution
# ---------------------------------------------------------------------------


def test_round_robin_placement_math():
    assert round_robin_placement(4, 2) == (0, 1, 0, 1)
    assert round_robin_placement(2, 4) == (0, 1)
    assert round_robin_placement(1, 1) == (0,)
    with pytest.raises(ValueError):
        round_robin_placement(0, 2)
    with pytest.raises(ValueError):
        round_robin_placement(2, 0)


def test_plan_graph_records_placement(resnet):
    api, cfg, _, _ = resnet
    plan = plan_graph(api.graph(cfg), F(1), n_stages=3, n_devices=2)
    assert plan.stage_plan.placement == (0, 1, 0)
    # placement is per stage: n_devices without n_stages is an error
    with pytest.raises(GraphError):
        plan_graph(api.graph(cfg), F(1), n_devices=2)


def test_resolve_stage_devices_forms():
    devs = jax.devices()
    # None/False: unplaced
    assert cnn.resolve_stage_devices(None, 3) is None
    assert cnn.resolve_stage_devices(False, 3) is None
    # int: round-robin over min(n, available)
    got = cnn.resolve_stage_devices(2, 3)
    pool = devs[: min(2, len(devs))]
    assert got == tuple(pool[s % len(pool)] for s in range(3))
    # ordinal sequence folds modulo the live device count (fallback)
    got = cnn.resolve_stage_devices((0, 1, 2), 3)
    assert len(got) == 3 and all(d in devs for d in got)
    # explicit Device objects round-robin
    got = cnn.resolve_stage_devices((devs[0],), 3)
    assert got == (devs[0],) * 3
    with pytest.raises(cnn.GraphExecutionError):
        cnn.resolve_stage_devices(0, 3)
    with pytest.raises(cnn.GraphExecutionError):
        cnn.resolve_stage_devices((), 3)


def test_device_pipeline_requires_placement(resnet):
    api, cfg, params, _ = resnet
    plan = api.partition(cfg, F(1), 2)
    pipe = cnn.stage_functions(api.graph(cfg), partition=plan)
    with pytest.raises(DevicePipelineError):
        DevicePipeline(pipe, params, placement=None)


def test_device_placement_rows_structural():
    assert device_placement_rows(3, 2) == [
        ("stage0_dev", 0),
        ("stage1_dev", 1),
        ("stage2_dev", 0),
    ]


# ---------------------------------------------------------------------------
# schedule correctness (single-CPU mesh: placement degrades to co-resident)
# ---------------------------------------------------------------------------


def test_gpipe_matches_staged_forward(resnet):
    api, cfg, params, x = resnet
    graph = api.graph(cfg)
    plan = api.partition(cfg, F(1), 3)
    sf = cnn.staged_forward(graph, partition=plan)
    ref = np.asarray(sf(params, x)["fc"])
    dp = DevicePipeline.build(graph, params, partition=plan, placement=True)
    assert dp.n_stages == 3
    assert len(dp.placement_ordinals()) == 3
    for mb in (1, 2, 4):  # M = 4, 2, 1 (1 micro-batch = degenerate schedule)
        out = np.asarray(dp.run(x, microbatch=mb))
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_single_stage_degenerate(resnet):
    api, cfg, params, x = resnet
    graph = api.graph(cfg)
    plan = api.partition(cfg, F(1), 1)
    dp = DevicePipeline.build(graph, params, partition=plan, placement=True)
    ref = np.asarray(api.apply(params, x, cfg))
    out = np.asarray(dp.run(x, microbatch=2))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_repeated_runs_and_donation_safety(resnet):
    # donated transfers must never leave a deleted array reachable: the
    # same DevicePipeline re-runs on fresh and on identical inputs
    api, cfg, params, x = resnet
    plan = api.partition(cfg, F(1), 2)
    dp = DevicePipeline.build(
        api.graph(cfg), params, partition=plan, placement=True
    )
    a = np.asarray(dp.run(x, microbatch=2))
    b = np.asarray(dp.run(x, microbatch=2))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(dp.run(x[::-1], microbatch=2))
    np.testing.assert_allclose(c, a[::-1], atol=1e-5, rtol=1e-5)


def test_measure_report_fields(resnet):
    api, cfg, params, x = resnet
    plan = api.partition(cfg, F(1), 2)
    dp = DevicePipeline.build(
        api.graph(cfg), params, partition=plan, placement=True
    )
    rep = dp.measure(x, microbatch=1, warmup=1, repeats=1)
    assert rep.frames == 4 and rep.n_micro == 4 and rep.n_stages == 2
    assert rep.microbatch == 1
    assert rep.utilization_bound == pytest.approx(4 / 5)
    assert len(rep.placement) == 2 and rep.n_devices >= 1
    assert rep.overlap_s > 0 and rep.sequential_s > 0
    assert rep.fps_overlap > 0 and rep.speedup > 0
    assert len(rep.stage_busy_s) == 2
    assert all(b > 0 for b in rep.stage_busy_s)


# ---------------------------------------------------------------------------
# serving engine: execute="devices"
# ---------------------------------------------------------------------------


def test_serve_execute_devices_matches_host(resnet):
    from repro.serving.cnn_stream import serve_frames
    from repro.serving.config import ServeConfig

    api, cfg, params, x = resnet
    graph = api.graph(cfg)
    host, _ = serve_frames(
        graph, params, x, input_rate=F(1), n_stages=2,
        config=ServeConfig(execute=True, microbatch=2),
    )
    placed, rep = serve_frames(
        graph, params, x, input_rate=F(1), n_stages=2,
        config=ServeConfig(execute="devices", microbatch=2),
    )
    np.testing.assert_allclose(placed, host, atol=1e-5, rtol=1e-5)
    assert rep.completed == 4


def test_serve_rejects_unknown_execute(resnet):
    from repro.serving.cnn_stream import ServingError, serve_frames
    from repro.serving.config import ServeConfig

    api, cfg, params, x = resnet
    with pytest.raises(ServingError):
        serve_frames(
            api.graph(cfg), params, x, input_rate=F(1), n_stages=2,
            config=ServeConfig(execute="device"),
        )
