"""Sharding rules: divisibility guards, rule coverage, constraint helper."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # single-device mesh with the production axis names: rules must emit
    # specs whose axis sizes (1) divide everything -> specs still correct.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_rules(mesh):
    cases = {
        "embed": ((1024, 64), P("model", None)),
        "blocks_dense/attn/wq": ((4, 64, 64), P(None, ("data",), "model")),
        "blocks_dense/attn/wo": ((4, 64, 64), P(None, "model", ("data",))),
        "blocks_dense/ffn/w_up": ((4, 64, 128), P(None, ("data",), "model")),
        "blocks_dense/ffn/w_down": ((4, 128, 64), P(None, "model", ("data",))),
        "blocks_moe/moe/w_up": ((4, 8, 64, 128),
                                P(None, None, ("data",), "model")),
        "blocks_dense/ln1": ((64,), P()),
        "step": ((), P()),
    }
    for path, (shape, want) in cases.items():
        got = shd.param_spec(path, shape, mesh)
        assert got == want, f"{path}: {got} != {want}"


def test_divisibility_guard():
    import numpy as np
    import types
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    assert shd._fits(92553, mesh1, "model")     # 1 divides everything
    # production-shaped stub (mesh_sizes only reads names + shape)
    fm = types.SimpleNamespace(axis_names=("data", "model"),
                               devices=np.zeros((16, 16)))
    assert not shd._fits(92553, fm, "model")    # internvl2 vocab is odd
    assert shd._fits(92544, fm, "model")
    assert not shd._fits(8, fm, "model")        # grok: 8 experts < 16-way
    assert shd._fits(512, fm, ("data", "model"))   # 512 % 256 == 0


def test_batch_and_serve_specs(mesh):
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    sh = shd.batch_specs(batch, mesh)
    assert sh["tokens"].spec == P(("data",))
    kv = jax.ShapeDtypeStruct((4, 8, 512, 2, 16), jnp.bfloat16)  # [L,B,S,kv,dh]
    sh = shd.serve_state_specs({"k": kv}, mesh)
    assert sh["k"].spec == P(None, ("data",), "model", None, None)
    # batch-1 long context: shard the sequence instead
    kv1 = jax.ShapeDtypeStruct((4, 1, 2048, 2, 16), jnp.bfloat16)
    sh = shd.serve_state_specs({"k": kv1}, mesh)
    assert sh["k"].spec == P(None, None, (("data", "model")), None, None)


def test_constrain_noop_outside_mesh():
    x = jnp.ones((4, 8, 16))
    y = shd.constrain(x, ("batch", "seq", None))
    assert (y == x).all()


def test_constrain_inside_mesh(mesh):
    def f(x):
        return shd.constrain(x, ("batch", "seq", None)) * 2
    x = jnp.ones((4, 8, 16))
    with mesh:
        out = jax.jit(f)(x)
    assert (out == 2).all()


def test_opt_state_shardings_follow_params(mesh):
    from repro.optim.optimizers import AdamWConfig, init as adam_init
    params = {"blocks_dense": {"ffn": {"w_up": jnp.zeros((4, 64, 128))}}}
    st = adam_init(AdamWConfig(), params)
    sh = shd.opt_state_shardings(st, params, mesh)
    assert sh.mu["blocks_dense"]["ffn"]["w_up"].spec == \
        P(None, ("data",), "model")
    assert sh.step.spec == P()
