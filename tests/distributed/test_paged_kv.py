"""Paged KV cache: allocator invariants + data-plane roundtrip."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.kv_cache import PagedKVCache, PagedKVConfig, capacity_for


def _cache(n_blocks=16, block_size=4):
    return PagedKVCache(PagedKVConfig(
        n_blocks=n_blocks, block_size=block_size, n_layers=2, n_kv=2,
        head_dim=8, dtype="float32"))


def test_alloc_extend_free_roundtrip():
    c = _cache()
    c.allocate(1, 6)                 # 2 blocks
    assert c.free_blocks == 14
    assert len(c.table(1)) == 2
    # extend within the partial block: no new block
    assert c.extend(1) is None
    assert c.extend(1) is None       # len 8 = exactly 2 blocks
    assert c.extend(1) is not None   # len 9 -> 3rd block
    assert c.free_blocks == 13
    c.free(1)
    assert c.free_blocks == 16


def test_admission_is_capacity_bound():
    c = _cache(n_blocks=4, block_size=4)
    assert c.can_admit(16)
    assert not c.can_admit(17)
    c.allocate(1, 12)
    assert c.can_admit(4) and not c.can_admit(5)
    with pytest.raises(MemoryError):
        c.allocate(2, 8)


@given(lengths=st.lists(st.integers(min_value=1, max_value=30),
                        min_size=1, max_size=8))
@settings(max_examples=30, deadline=None)
def test_fragmentation_bounded(lengths):
    c = _cache(n_blocks=128, block_size=4)
    for i, n in enumerate(lengths):
        c.allocate(i, n)
    frag = c.fragmentation()
    # waste is < 1 block per sequence
    alloc = sum(len(c.table(i)) for i in range(len(lengths))) * 4
    assert frag * alloc < len(lengths) * 4
    # no block leaked / double-owned
    owned = [b for i in range(len(lengths)) for b in c.table(i)]
    assert len(owned) == len(set(owned))
    assert len(owned) + c.free_blocks == 128


def test_write_gather_roundtrip():
    c = _cache()
    c.allocate(7, 6)
    toks = []
    for pos in range(6):
        k = jnp.full((2, 2, 8), float(pos + 1))
        v = -k
        c.write_token(7, (k, v), pos)
        toks.append(float(pos + 1))
    k, v = c.gather_kv(7)
    assert k.shape == (2, 6, 2, 8)
    np.testing.assert_allclose(np.asarray(k[0, :, 0, 0]), toks)
    np.testing.assert_allclose(np.asarray(v), -np.asarray(k))


def test_capacity_sizing():
    # 1000 tok/s, 2 s residency, 16-token blocks -> >= 157 blocks
    assert capacity_for(1000, 2.0, 16) == 157
