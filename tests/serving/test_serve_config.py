"""ServeConfig (serving/config.py): one surface, two calling styles.

Pins the satellite contract: the deprecated kwargs build exactly the
config they claim to (event-for-event identical runs), the config is
frozen, mixing the styles is an error, and ``flush_after_ticks`` now
threads through every front door (engine, ``CNNApi.serve``,
``FleetScheduler`` — including per-tenant ``TenantWorkload.config``
with its own overload policy).
"""
import dataclasses
from fractions import Fraction as F

import pytest

from repro.core.graph import plan_graph
from repro.fleet import (
    Chip,
    FleetScheduler,
    Tenant,
    TenantWorkload,
    chip_pool,
    plan_pool,
)
from repro.models.registry import get_cnn_api
from repro.serving import ServeConfig, ShedPolicy
from repro.serving.cnn_stream import (
    CNNStreamEngine,
    ServingError,
    best_rate_frames,
)
from repro.serving.scenarios import adversarial


def _setup(family="resnet18", n_stages=2, rate=F(3)):
    api = get_cnn_api(family)
    cfg = api.make_config(input_hw=(32, 32), num_classes=10)
    graph = cfg.graph()
    return api, cfg, graph, plan_graph(graph, rate, n_stages=n_stages)


def _report_key(rep):
    """Everything event-order-dependent a run produces."""
    return (
        rep.makespan_ticks,
        rep.latency_ticks,
        rep.service_latency_ticks,
        rep.queue_events,
        rep.request_queue_peak,
        [(s.busy_cycles, s.stall_cycles, s.batches_served) for s in rep.stages],
    )


def test_kwargs_shim_equals_config_event_for_event():
    """The deprecated engine kwargs + run() overrides produce the exact
    run the equivalent ServeConfig does."""
    _, _, graph, plan = _setup()
    with pytest.warns(DeprecationWarning):
        legacy = CNNStreamEngine(graph, None, plan, microbatch=3,
                                 execute=False)
    for _ in range(17):
        legacy.submit(None)
    legacy_rep = legacy.run(arrival_rate=F(2), flush_after_ticks=F(3))

    cfg = ServeConfig(microbatch=3, execute=False, arrival=F(2),
                      flush_after_ticks=F(3))
    modern = CNNStreamEngine(graph, None, plan, cfg)
    for _ in range(17):
        modern.submit(None)
    modern_rep = modern.run()

    assert _report_key(legacy_rep) == _report_key(modern_rep)
    # the shim builds exactly the config the init kwargs name (run()
    # overrides stay per-run, they do not mutate the engine config)
    assert legacy.config == ServeConfig(microbatch=3, execute=False)


def test_run_kwargs_override_config():
    """Per-run kwargs beat the engine config (the PR 6 calling style)."""
    _, _, graph, plan = _setup()
    cfg = ServeConfig(execute=False, arrival=F(1, 2))
    a = CNNStreamEngine(graph, None, plan, cfg)
    b = CNNStreamEngine(graph, None, plan, cfg)
    for eng in (a, b):
        for _ in range(8):
            eng.submit(None)
    rep_override = a.run(arrival_rate=F(2))
    rep_config = b.run()
    assert rep_override.arrival_rate == F(2)
    assert rep_config.arrival_rate == F(1, 2)
    assert rep_override.makespan_ticks < rep_config.makespan_ticks


def test_config_is_frozen_and_with_copies():
    cfg = ServeConfig(microbatch=2)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.microbatch = 4
    cfg2 = cfg.with_(microbatch=4, arrival=F(3))
    assert cfg2.microbatch == 4 and cfg2.arrival == F(3)
    assert cfg.microbatch == 2  # original untouched


def test_mixing_config_and_kwargs_is_an_error():
    _, _, graph, plan = _setup()
    with pytest.raises(ServingError):
        CNNStreamEngine(graph, None, plan, ServeConfig(), microbatch=2)


def test_api_serve_threads_config_and_flush():
    """CNNApi.serve accepts config= (incl. flush_after_ticks) — the
    partial micro-batch flushes on the straggler bound instead of
    waiting for the stream end."""
    api, cfg, _, _ = _setup()
    _, rep = api.serve(
        None, 9, cfg, input_rate=F(3), n_stages=2,
        config=ServeConfig(microbatch=4, execute=False, arrival=F(1, 4),
                           flush_after_ticks=F(2)))
    assert rep.completed == 9
    assert rep.microbatch == 4
    # flush bound 2 ticks < inter-arrival 4 ticks: every frame flushes
    # alone instead of waiting to fill the 4-frame batch
    assert all(s.batches_served >= 3 for s in rep.stages)


@pytest.fixture(scope="module")
def pool():
    tenants = (
        Tenant("alpha", "resnet18", F(1, 2), input_hw=(32, 32),
               num_classes=10),
        Tenant("beta", "mobilenet_v2", F(1, 2), input_hw=(32, 32),
               num_classes=10),
    )
    chips = (Chip("big0", bram36=4096),) + chip_pool(4)
    return plan_pool(tenants, chips, s_options=(1, 2), try_replicate=False)


def test_fleet_scheduler_takes_config(pool):
    sched = FleetScheduler(pool, config=ServeConfig(execute=False))
    rep = sched.serve([
        TenantWorkload("alpha", 8, flush_after_ticks=F(1)),
        TenantWorkload("beta", 6, arrival_rate=F(1, 2)),
    ])
    assert rep.reports["alpha"].completed == 8
    assert rep.reports["beta"].completed == 6
    # unified schema: per-tenant summaries + canonical rows
    rows = dict(rep.to_rows())
    assert "alpha/served" in rows and "beta/latency" in rows


def test_fleet_legacy_kwargs_warn_and_mixing_raises(pool):
    with pytest.warns(DeprecationWarning):
        FleetScheduler(pool, execute=False)
    with pytest.raises(ServingError):
        FleetScheduler(pool, config=ServeConfig(), execute=False)


def test_fleet_per_tenant_policy(pool):
    """TenantWorkload.config carries a per-tenant overload policy: one
    tenant sheds under its SLA while the other serves normally."""
    alpha_plan = pool.chosen["alpha"].plan
    br = best_rate_frames(alpha_plan)
    shed_cfg = ServeConfig(
        execute=False,
        arrival=adversarial(br, margin=F(3, 2)),
        overload=ShedPolicy(deadline_ticks=F(12)),
    )
    sched = FleetScheduler(pool, config=ServeConfig(execute=False))
    rep = sched.serve([
        TenantWorkload("alpha", 120, config=shed_cfg),
        TenantWorkload("beta", 8),
    ])
    a, b = rep.reports["alpha"], rep.reports["beta"]
    assert a.shed > 0 and a.completed + a.shed == 120
    assert a.within_queue_bounds
    assert b.shed == 0 and b.completed == 8
    assert b.stall_free


def test_workload_config_excludes_legacy_fields(pool):
    with pytest.raises(ServingError):
        TenantWorkload("alpha", 8, arrival_rate=F(2), config=ServeConfig())
