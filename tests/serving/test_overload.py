"""Overload policies (serving/overload.py + the engine hooks).

The issue's acceptance properties, as tests:

* under adversarial arrivals the inter-stage queue bounds are never
  exceeded (excess lives outside the pipeline, shed or parked);
* ``admitted + shed == submitted`` — shedding is exhaustive accounting,
  never double-counted;
* shedding never reorders survivors (admission and completion stay in
  rid order);
* a plan switch mid-stream is bit-exact vs running each plan segment
  monolithically (a batch never straddles a switch).
"""
from fractions import Fraction as F

import jax
import numpy as np
import pytest

from repro.core.graph import plan_graph
from repro.models.registry import get_cnn_api
from repro.serving import ServeConfig
from repro.serving.cnn_stream import (
    CNNStreamEngine,
    ServingError,
    best_rate_frames,
    sustainable_rate_cycles,
)
from repro.serving.overload import (
    LadderRung,
    OverloadError,
    PlanLadder,
    ShedPolicy,
    SwitchPolicy,
)
from repro.serving.scenarios import adversarial, bursty

DEADLINE = F(24)


def _setup(family="resnet18", n_stages=2, rate=F(3)):
    api = get_cnn_api(family)
    cfg = api.make_config(input_hw=(32, 32), num_classes=10)
    graph = cfg.graph()
    return api, cfg, graph, plan_graph(graph, rate, n_stages=n_stages)


def _run(graph, plan, *, arrival, overload, n, microbatch=4):
    cfg = ServeConfig(
        microbatch=microbatch, execute=False, arrival=arrival,
        overload=overload)
    eng = CNNStreamEngine(graph, None, plan, cfg)
    for _ in range(n):
        eng.submit(None)
    return eng, eng.run()


# ---------------------------------------------------------------------------
# the ladder
# ---------------------------------------------------------------------------

def test_ladder_rungs_strictly_ascend_and_base_is_factor_one():
    _, _, graph, plan = _setup()
    ladder = PlanLadder.build(graph, F(3), n_stages=2, rate_factors=(1, 2))
    assert len(ladder.rungs) >= 2
    rates = [r.rate_cycles for r in ladder.rungs]
    assert all(b > a for a, b in zip(rates, rates[1:]))
    assert ladder.rungs[0].plan.input_rate == F(3)
    for rung in ladder.rungs:
        assert rung.rate_cycles == sustainable_rate_cycles(rung.plan)
    assert "->" in ladder.describe()


def test_ladder_requires_base_factor():
    _, _, graph, _ = _setup()
    with pytest.raises(OverloadError):
        PlanLadder.build(graph, F(3), n_stages=2, rate_factors=(2, 4))


def test_ladder_rejects_nonascending_rungs():
    _, _, graph, plan = _setup()
    rung = LadderRung("a", plan, sustainable_rate_cycles(plan))
    with pytest.raises(OverloadError):
        PlanLadder(rungs=(rung, rung))  # equal rate: not an improvement


def test_switch_policy_target_hysteresis():
    _, _, graph, plan = _setup()
    ladder = PlanLadder.build(graph, F(3), n_stages=2, rate_factors=(1, 2))
    pol = SwitchPolicy(ladder, down_headroom=F(3, 4))
    r0, r1 = ladder.rungs[0].rate_cycles, ladder.rungs[1].rate_cycles
    # above rung 0's capacity -> up
    assert pol.target(r0 * 2, active=0) == 1
    # above every rung -> top rung
    assert pol.target(r1 * 2, active=0) == len(ladder.rungs) - 1
    # down only with headroom: just-below-r0 stays on 1...
    assert pol.target(r0 * F(9, 10), active=1) == 1
    # ...well-below-r0 switches down
    assert pol.target(r0 * F(1, 2), active=1) == 0


def test_policy_validation():
    with pytest.raises(OverloadError):
        ShedPolicy(deadline_ticks=F(0))
    _, _, graph, plan = _setup()
    ladder = PlanLadder.build(graph, F(3), n_stages=2, rate_factors=(1, 2))
    with pytest.raises(OverloadError):
        SwitchPolicy(ladder, window_ticks=F(0))
    with pytest.raises(OverloadError):
        SwitchPolicy(ladder, down_headroom=F(2))
    with pytest.raises(ServingError):
        # unknown policy object
        CNNStreamEngine(graph, None, plan, ServeConfig(overload=object()))


def test_switch_engine_must_start_from_base_rung():
    _, _, graph, plan = _setup()
    ladder = PlanLadder.build(graph, F(3), n_stages=2, rate_factors=(1, 2))
    other = plan_graph(graph, F(3), n_stages=2)
    cfg = ServeConfig(execute=False, overload=SwitchPolicy(ladder))
    with pytest.raises(ServingError):
        CNNStreamEngine(graph, None, other, cfg)


# ---------------------------------------------------------------------------
# shedding properties (adversarial arrivals)
# ---------------------------------------------------------------------------

def test_adversarial_shed_accounting_and_bounds():
    """admitted + shed == submitted; queue bounds hold; p99 of survivors
    is pinned near the deadline while the no-policy baseline drifts."""
    _, _, graph, plan = _setup()
    br = best_rate_frames(plan)
    adv = adversarial(br, margin=F(5, 4))
    eng, rep = _run(
        graph, plan, arrival=adv, overload=ShedPolicy(DEADLINE), n=200)
    assert rep.completed + rep.shed == rep.frames == 200
    assert rep.shed > 0
    assert rep.shed == len(rep.shed_rids)
    assert rep.within_queue_bounds  # pipeline queues never over cap
    assert rep.stall_free  # shedding happens outside the pipeline
    # every shed frame really was never admitted/served
    shed = set(rep.shed_rids)
    for r in eng._requests:
        if r.rid in shed:
            assert r.t_admit is None and r.t_done is None
        else:
            assert r.t_done is not None
    # the SLA holds with slack for projection error (one micro-batch)
    deadline_slack = float(DEADLINE) + 4
    assert max(float(t) for t in rep.latency_ticks) <= deadline_slack


def test_shed_never_reorders_survivors():
    _, _, graph, plan = _setup()
    br = best_rate_frames(plan)
    scen = bursty(2 * br, burst=16, gap=1, burst_jitter=4, seed=3)
    eng, rep = _run(
        graph, plan, arrival=scen, overload=ShedPolicy(F(12)), n=150)
    assert rep.shed > 0
    admitted = [r for r in eng._requests if r.t_admit is not None]
    by_admit = sorted(admitted, key=lambda r: (r.t_admit, r.rid))
    assert [r.rid for r in by_admit] == sorted(r.rid for r in admitted)
    by_done = sorted(admitted, key=lambda r: (r.t_done, r.rid))
    assert [r.rid for r in by_done] == sorted(r.rid for r in admitted)


def test_baseline_queue_growth_vs_shed():
    """Without a policy the request queue grows with the stream length;
    with shedding it plateaus below the deadline's worth of backlog."""
    _, _, graph, plan = _setup()
    br = best_rate_frames(plan)
    adv = adversarial(br, margin=F(5, 4))
    peaks = {}
    for n in (100, 200):
        _, rep = _run(graph, plan, arrival=adv, overload=None, n=n)
        peaks[n] = rep.request_queue_peak
    assert peaks[200] > peaks[100]  # unbounded growth signature
    _, shed100 = _run(
        graph, plan, arrival=adv, overload=ShedPolicy(DEADLINE), n=100)
    _, shed200 = _run(
        graph, plan, arrival=adv, overload=ShedPolicy(DEADLINE), n=200)
    assert shed200.request_queue_peak <= shed100.request_queue_peak + 2


# ---------------------------------------------------------------------------
# switching properties
# ---------------------------------------------------------------------------

def test_switch_under_adversarial_serves_everything_bounded():
    _, _, graph, plan = _setup()
    ladder = PlanLadder.build(graph, F(3), n_stages=2, rate_factors=(1, 2))
    plan = ladder.rungs[0].plan
    br = best_rate_frames(plan)
    eng, rep = _run(
        graph, plan, arrival=adversarial(br),
        overload=SwitchPolicy(ladder), n=200)
    assert rep.completed == rep.frames == 200
    assert rep.shed == 0
    assert len(rep.switches) >= 1
    assert rep.within_queue_bounds
    # after the up-switch the active rung absorbs 17/16 br: the request
    # queue stops growing (compare against a longer run)
    _, rep2 = _run(
        graph, plan, arrival=adversarial(br),
        overload=SwitchPolicy(ladder), n=400)
    assert rep2.request_queue_peak <= rep.request_queue_peak + 2
    # per-(segment, stage) rows carry their rung; switches are recorded
    # as (tick, from, to) with distinct rungs
    assert {s.rung for s in rep.stages} >= {a for _, a, b in rep.switches}
    for _, frm, to in rep.switches:
        assert frm != to


def test_switch_mid_stream_bit_exact_vs_monolithic_segments():
    """The headline switching invariant: a batch never straddles a
    switch, so every frame is served end-to-end by exactly one rung and
    its output is bitwise identical to serving that rung's plan
    monolithically over the same frames."""
    api, cfg, graph, _ = _setup("mobilenet_v2", n_stages=2, rate=F(2))
    ladder = PlanLadder.build(graph, F(2), n_stages=2, rate_factors=(1, 2))
    plan = ladder.rungs[0].plan
    br = best_rate_frames(plan)
    params = api.init(cfg, jax.random.PRNGKey(0))
    frames = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (10, 32, 32, 3)),
        dtype=np.float32)

    # a short decision window so the 8-frame burst registers as > the
    # base rung's capacity within a 10-frame stream
    scen = bursty(2 * br, burst=8, gap=12)
    serve_cfg = ServeConfig(
        microbatch=2, execute=True, arrival=scen,
        overload=SwitchPolicy(ladder, window_ticks=F(4)))
    eng = CNNStreamEngine(graph, params, plan, serve_cfg)
    eng.submit_all(frames)
    rep = eng.run()
    assert rep.completed == len(frames)
    assert len(rep.switches) >= 1, "scenario must actually trigger a switch"
    out = eng.outputs()

    # regroup frames by the rung that served them; re-serve each group
    # through that rung's plan alone (no policy) and compare bitwise
    rungs_used = sorted({r.rung for r in eng._requests})
    assert len(rungs_used) >= 2
    for rung_idx in rungs_used:
        rids = [r.rid for r in eng._requests if r.rung == rung_idx]
        rung_plan = ladder.rungs[rung_idx].plan
        mono_cfg = ServeConfig(microbatch=2, execute=True)
        mono = CNNStreamEngine(rung_plan.graph, params, rung_plan, mono_cfg)
        mono.submit_all(frames[rids])
        mono.run()
        mono_out = mono.outputs()
        assert np.array_equal(out[rids], mono_out), (
            f"rung {rung_idx} outputs differ from monolithic serving"
        )
