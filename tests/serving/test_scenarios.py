"""Traffic scenarios (serving/scenarios.py).

The contract: an ArrivalProcess is a seeded, deterministic map from a
frame count to exact-Fraction submit times in ticks — nondecreasing,
reproducible across calls, and (for Constant) identical to the legacy
``run(arrival_rate=)`` timing.
"""
from fractions import Fraction as F

import pytest

from repro.core.graph import plan_graph
from repro.models.registry import get_cnn_api
from repro.serving import ServeConfig
from repro.serving.cnn_stream import CNNStreamEngine, best_rate_frames
from repro.serving.scenarios import (
    Bursty,
    Diurnal,
    ScenarioError,
    adversarial,
    bursty,
    constant,
    diurnal,
)


def _plan(family="resnet18", n_stages=2, rate=F(3)):
    api = get_cnn_api(family)
    cfg = api.make_config(input_hw=(32, 32), num_classes=10)
    graph = cfg.graph()
    return graph, plan_graph(graph, rate, n_stages=n_stages)


# ---------------------------------------------------------------------------
# constant: the legacy timing, exactly
# ---------------------------------------------------------------------------

def test_constant_times_match_legacy_spacing():
    c = constant(F(3, 2))
    assert c.times(4) == [F(0), F(2, 3), F(4, 3), F(2)]
    assert c.mean_rate(4) == F(3, 2)


def test_constant_process_is_event_identical_to_legacy_rate():
    """run(arrival_rate=r) and ServeConfig(arrival=constant(r)) are the
    same run, event for event."""
    graph, plan = _plan()
    reps = []
    for arrival in (F(3, 2), constant(F(3, 2))):
        cfg = ServeConfig(microbatch=2, execute=False, arrival=arrival)
        eng = CNNStreamEngine(graph, None, plan, cfg)
        for _ in range(12):
            eng.submit(None)
        reps.append(eng.run())
    a, b = reps
    assert a.makespan_ticks == b.makespan_ticks
    assert a.latency_ticks == b.latency_ticks
    assert a.queue_events == b.queue_events
    assert [s.busy_cycles for s in a.stages] == [s.busy_cycles for s in b.stages]


# ---------------------------------------------------------------------------
# bursty: seeded on/off
# ---------------------------------------------------------------------------

def test_bursty_unjittered_shape():
    """burst frames at on_rate, then a gap, repeated — exact rationals."""
    b = bursty(F(2), burst=3, gap=4)
    # bursts of 3 at spacing 1/2, burst span 3/2, next burst at +gap
    assert b.times(7) == [
        F(0), F(1, 2), F(1),
        F(11, 2), F(6), F(13, 2),
        F(11),
    ]


def test_bursty_jitter_is_seeded_and_deterministic():
    a = bursty(F(2), burst=8, gap=6, burst_jitter=3, gap_jitter=2, seed=7)
    b = bursty(F(2), burst=8, gap=6, burst_jitter=3, gap_jitter=2, seed=7)
    c = bursty(F(2), burst=8, gap=6, burst_jitter=3, gap_jitter=2, seed=8)
    assert a.times(40) == b.times(40)  # same seed -> same process
    assert a.times(40) != c.times(40)  # different seed -> different draws
    ts = a.times(40)
    assert all(t2 >= t1 for t1, t2 in zip(ts, ts[1:]))
    assert all(isinstance(t, F) for t in ts)


def test_bursty_validation():
    with pytest.raises(ScenarioError):
        Bursty(on_rate=F(0))
    with pytest.raises(ScenarioError):
        Bursty(burst=0)
    with pytest.raises(ScenarioError):
        Bursty(gap=2, gap_jitter=3)  # jitter could make the gap negative
    with pytest.raises(ScenarioError):
        Bursty(burst=4, burst_jitter=4)  # jitter could empty a burst


# ---------------------------------------------------------------------------
# diurnal: exact inhomogeneous inversion
# ---------------------------------------------------------------------------

def test_diurnal_inverts_integrated_rate_exactly():
    """rate 1 for 4 ticks, idle 2 ticks, cycling: arrivals land exactly
    where the integrated rate crosses each integer — the zero-rate night
    is skipped, the pending fraction carries across the boundary."""
    d = diurnal(((F(1), F(4)), (F(0), F(2))))
    assert d.times(9) == [
        F(0), F(1), F(2), F(3), F(4),
        F(7), F(8), F(9), F(10),
    ]


def test_diurnal_fractional_carry_across_phases():
    # rate 1/2 for 3 ticks integrates 3/2: one arrival at t=2, then 1/2
    # credit spent into the rate-2 phase -> next arrival 1/4 tick in
    d = diurnal(((F(1, 2), F(3)), (F(2), F(1))))
    ts = d.times(4)
    assert ts[0] == F(0)
    assert ts[1] == F(2)
    assert ts[2] == F(3) + F(1, 4)
    assert ts[3] == F(3) + F(3, 4)


def test_diurnal_validation():
    with pytest.raises(ScenarioError):
        Diurnal(phases=())
    with pytest.raises(ScenarioError):
        Diurnal(phases=((F(1), F(0)),))  # zero-length phase
    with pytest.raises(ScenarioError):
        Diurnal(phases=((F(0), F(2)),))  # all-zero rates never arrive


# ---------------------------------------------------------------------------
# adversarial: just above BestRate
# ---------------------------------------------------------------------------

def test_adversarial_sits_just_above_best_rate():
    _, plan = _plan()
    br = best_rate_frames(plan)
    adv = adversarial(br)
    assert adv.name == "adversarial"
    assert adv.rate == br * F(17, 16)
    assert adv.rate > br
    with pytest.raises(ScenarioError):
        adversarial(br, margin=F(1))  # must be strictly above
    with pytest.raises(ScenarioError):
        adversarial(F(0))
