"""Streaming CNN serving engine (serving/cnn_stream.py).

Covers the acceptance surface of the request-level rate calculus:

* the engine's per-stage telemetry against the analytical model that
  ``core.schedule.simulate_graph`` validates at pixel granularity —
  measured occupancy == max node demand/capacity, zero stalls whenever
  the admitted rate <= BestRate;
* bounded queues (within the stream-buffer-derived caps) and admission
  throttling to exactly BestRate under overload;
* served outputs vs the monolithic ``apply_graph``: fp32 allclose /
  bit-exact with the same kernel plan, int8 exact, with frames tracked
  by request id across micro-batch boundaries (including the padded
  final partial batch).
"""
from fractions import Fraction as F

import jax
import numpy as np
import pytest

from repro.core.graph import plan_graph
from repro.core.schedule import simulate_graph
from repro.models import cnn
from repro.models.registry import get_cnn_api
from repro.serving.cnn_stream import (
    CNNStreamEngine,
    ServingError,
    best_rate_frames,
    queue_caps_batches,
    stage_rates,
)

FAMILIES = ("mobilenet_v2", "resnet18")
ALL_FAMILIES = ("mobilenet_v1", "mobilenet_v2", "resnet18", "resnet34")


def _setup(family, n_stages, rate=F(3), hw=32):
    api = get_cnn_api(family)
    cfg = api.make_config(input_hw=(hw, hw), num_classes=10)
    graph = cfg.graph()
    plan = plan_graph(graph, rate, n_stages=n_stages)
    return api, cfg, graph, plan


def _timing_run(plan, graph, *, n_frames, arrival, microbatch=1):
    eng = CNNStreamEngine(graph, None, plan, microbatch=microbatch,
                          execute=False)
    for _ in range(n_frames):
        eng.submit(None)
    return eng.run(arrival_rate=arrival)


# ---------------------------------------------------------------------------
# analytics: stage rates, BestRate, queue caps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_stage_utilization_is_max_node_ratio(family):
    """A stage's request-level utilization is exactly the max over its
    nodes of demand/capacity — the DSE quantity simulate_graph measures."""
    _, _, graph, plan = _setup(family, n_stages=3)
    for sr in stage_rates(plan):
        want = max(
            plan.impls[n].demand / plan.impls[n].capacity for n in sr.nodes
        )
        assert sr.utilization == want


@pytest.mark.parametrize("family", FAMILIES)
def test_best_rate_is_inverse_bottleneck_utilization(family):
    """Eq. 10 lifted: BestRate (frames/tick) == 1 / max node utilization."""
    _, _, graph, plan = _setup(family, n_stages=3)
    worst = max(i.demand / i.capacity for i in plan.impls.values())
    assert best_rate_frames(plan) == 1 / worst


@pytest.mark.parametrize("n_stages", [1, 2, 3])
def test_queue_caps_are_double_buffer_plus_stream_bits(n_stages):
    """Inter-stage queues: 2 micro-batches (double buffering) plus the
    stream-buffer pixel bound converted to whole frames — which floors
    to 0 extra for real frame sizes."""
    _, _, graph, plan = _setup("resnet18", n_stages=n_stages)
    caps = queue_caps_batches(plan, microbatch=2)
    assert len(caps) == n_stages
    assert all(c >= 2 for c in caps)
    # cut FIFOs hold pixels, not frames: far below one frame per cut
    for s in range(1, n_stages):
        assert caps[s] == 2


# ---------------------------------------------------------------------------
# telemetry vs the analytical bounds (simulate_graph cross-check)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_occupancy_matches_simulate_graph(family):
    """The engine's measured stage occupancy at the plan rate equals the
    analytical bound — the same per-node utilization simulate_graph
    measures at pixel granularity (zero stalls in both models)."""
    _, _, graph, plan = _setup(family, n_stages=3)
    sim = simulate_graph(plan, n_pixels=256)
    assert sim.stall_free
    assert sim.within_bounds

    rep = _timing_run(plan, graph, n_frames=64, arrival=F(1))
    assert rep.stall_free
    for sr, stage_rep in zip(stage_rates(plan), rep.stages):
        # engine (request level) vs analytic bound: tight — the model is
        # exact up to the finite-run tail
        assert stage_rep.measured_occupancy == pytest.approx(
            float(stage_rep.analytic_occupancy), abs=0.02
        )
        # analytic bound vs simulate_graph's measured per-node util
        # (pixel level, edge effects at the tail => looser tolerance)
        sim_util = max(sim.traces[n].util for n in sr.nodes)
        assert float(sr.utilization) == pytest.approx(
            sim_util, rel=0.15, abs=0.05
        )


@pytest.mark.parametrize("family", ALL_FAMILIES)
@pytest.mark.parametrize("n_stages", [1, 2, 3])
def test_zero_stalls_at_or_below_best_rate(family, n_stages):
    """Acceptance: zero stalls and bounded queues for every family at
    S in {1, 2, 3} whenever the admitted rate <= BestRate."""
    _, _, graph, plan = _setup(family, n_stages=n_stages)
    br = best_rate_frames(plan)
    for arrival in (F(1, 2), F(1), br):
        rep = _timing_run(plan, graph, n_frames=32, arrival=arrival,
                          microbatch=2)
        assert rep.admitted_rate == min(arrival, br)
        assert rep.stall_free, (family, n_stages, arrival)
        assert rep.within_queue_bounds
        assert rep.completed == 32


@pytest.mark.parametrize("family", FAMILIES)
def test_backpressure_above_best_rate(family):
    """Above BestRate the engine admits at exactly BestRate: queues stay
    within their caps, the bottleneck saturates, and the excess waits in
    the request queue outside the pipeline."""
    _, _, graph, plan = _setup(family, n_stages=3)
    br = best_rate_frames(plan)
    rep = _timing_run(plan, graph, n_frames=48, arrival=2 * br,
                      microbatch=2)
    assert rep.admitted_rate == br
    assert rep.completed == 48
    assert rep.within_queue_bounds  # stable bounded queues: the claim
    assert rep.request_queue_peak > 0  # overload parked outside
    bott = rep.stages[rep.bottleneck_stage]
    assert bott.measured_occupancy == pytest.approx(1.0, abs=0.02)
    assert bott.stall_cycles == 0  # the bottleneck itself never starves
    # served no faster than BestRate (finite-run drain makes it slower)
    assert rep.throughput <= br


def test_tick_telemetry_series():
    """Per-tick occupancy/queue-depth traces: occupancy in [0, 1] and
    ~1 at the bottleneck mid-run; queue depths never exceed the caps."""
    _, _, graph, plan = _setup("resnet18", n_stages=2)
    rep = _timing_run(plan, graph, n_frames=32, arrival=F(1))
    for s, stage_rep in enumerate(rep.stages):
        occ = rep.tick_occupancy(s)
        assert all(0.0 <= v <= 1.0 + 1e-9 for v in occ)
        depths = rep.tick_queue_depth(s)
        assert max(depths) <= stage_rep.queue_cap_batches
    bott = rep.bottleneck_stage
    mid = rep.tick_occupancy(bott)[2:-2]
    assert all(v == pytest.approx(1.0) for v in mid)


# ---------------------------------------------------------------------------
# served outputs vs apply_graph (rid-tracked across micro-batches)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_served_outputs_fp32_allclose(family):
    """Frames served through the pipelined engine (jitted stages, frames
    spread across micro-batches incl. a padded partial batch, admission
    above BestRate so queues/backpressure engage) match the monolithic
    apply_graph per request id."""
    api, cfg, graph, plan = _setup(family, n_stages=2)
    params = api.init(cfg, jax.random.key(0))
    frames = np.asarray(jax.random.normal(jax.random.key(1), (5, 32, 32, 3)))
    eng = CNNStreamEngine(graph, params, plan, microbatch=2, dtype=cfg.dtype)
    eng.submit_all(frames)
    rep = eng.run(arrival_rate=2 * best_rate_frames(plan))
    assert rep.completed == 5
    out = eng.outputs()
    ref = np.asarray(api.apply(params, frames, cfg))
    assert np.allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_served_outputs_bit_exact_with_pinned_plan():
    """With the batch-pinned rate-matched kernel plan, serving is
    bit-exact vs apply_graph(plan=...) on the same micro-batches: the
    engine runs the *same* kernels with the *same* tiles."""
    api, cfg, graph, plan = _setup("resnet18", n_stages=2)
    params = api.init(cfg, jax.random.key(0))
    frames = np.asarray(jax.random.normal(jax.random.key(1), (4, 32, 32, 3)))
    kp = plan.kernel_plan(batch=2)
    eng = CNNStreamEngine(graph, params, plan, microbatch=2, kernel_plan=kp,
                          dtype=cfg.dtype)
    eng.submit_all(frames)
    eng.run(arrival_rate=F(1))
    out = eng.outputs()
    ref = np.concatenate([
        np.asarray(api.apply(params, frames[i:i + 2], cfg, plan=kp))
        for i in range(0, 4, 2)
    ])
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("family", FAMILIES)
def test_served_int8_bit_exact(family):
    """The int8 datapath served through the engine (eager stages so the
    op sequence is identical) is bit-exact vs apply_int8 on the same
    micro-batches."""
    api, cfg, graph, plan = _setup(family, n_stages=2)
    params = api.init(cfg, jax.random.key(0))
    frames = np.asarray(jax.random.normal(jax.random.key(1), (4, 32, 32, 3)))
    q, s = api.quantize(params)
    deq = cnn.dequantize_params(q, s, cfg.dtype)
    eng = CNNStreamEngine(graph, deq, plan, microbatch=2, dtype=cfg.dtype,
                          jit=False)
    eng.submit_all(frames)
    eng.run(arrival_rate=2 * best_rate_frames(plan))
    out = eng.outputs()
    ref = np.concatenate([
        np.asarray(api.apply_int8(q, s, frames[i:i + 2], cfg))
        for i in range(0, 4, 2)
    ])
    assert np.array_equal(out, ref)


def test_rid_tracking_under_out_of_order_submission():
    """Outputs map to their requests even when rids are submitted out of
    order: frame content is tied to rid, not to arrival position."""
    api, cfg, graph, plan = _setup("mobilenet_v2", n_stages=2)
    params = api.init(cfg, jax.random.key(0))
    frames = np.asarray(jax.random.normal(jax.random.key(1), (4, 32, 32, 3)))
    eng = CNNStreamEngine(graph, params, plan, microbatch=3, dtype=cfg.dtype)
    order = [2, 0, 3, 1]
    for rid in order:
        eng.submit(frames[rid], rid=rid)
    eng.run(arrival_rate=F(1))
    out = eng.outputs()  # stacked in rid order
    ref = np.asarray(api.apply(params, frames, cfg))
    assert np.allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_requires_stage_partition():
    _, cfg, graph, _ = _setup("mobilenet_v2", n_stages=1)
    unstaged = plan_graph(graph, F(3))  # no n_stages
    with pytest.raises(ServingError, match="stage partition"):
        CNNStreamEngine(graph, None, unstaged, execute=False)


def test_rejects_mismatched_pin():
    _, cfg, graph, plan = _setup("mobilenet_v2", n_stages=2)
    kp = plan.kernel_plan(batch=4)
    with pytest.raises(ServingError, match="pinned to batch"):
        CNNStreamEngine(graph, None, plan, microbatch=2, kernel_plan=kp,
                        execute=False)


def test_rejects_empty_run():
    _, cfg, graph, plan = _setup("mobilenet_v2", n_stages=2)
    eng = CNNStreamEngine(graph, None, plan, execute=False)
    with pytest.raises(ServingError, match="no frames"):
        eng.run()


def test_lm_engine_routes_cnn_configs_here():
    """The token-stream Engine names this engine when handed a CNN
    config (which carries no .family — the structural check must fire
    before any attribute access)."""
    from repro.serving import Engine

    cfg = get_cnn_api("resnet18").make_config(input_hw=(32, 32),
                                              num_classes=10)
    with pytest.raises(ValueError, match="CNNStreamEngine"):
        Engine(cfg, None)


def test_timing_only_has_no_outputs():
    _, cfg, graph, plan = _setup("mobilenet_v2", n_stages=2)
    eng = CNNStreamEngine(graph, None, plan, execute=False)
    eng.submit(None)
    eng.run()
    with pytest.raises(ServingError, match="execute=False"):
        eng.outputs()


# ---------------------------------------------------------------------------
# straggler flush (flush_after_ticks)
# ---------------------------------------------------------------------------

def test_flush_after_ticks_bounds_straggler_latency():
    """At arrival rates far below the micro-batch fill rate, a partial
    batch used to wait for the whole stream; the flush knob bounds the
    wait to ``flush_after_ticks`` ticks per straggler."""
    _, cfg, graph, plan = _setup("mobilenet_v2", n_stages=2)
    mb, n, arrival = 4, 6, F(1, 8)  # one frame every 8 ticks

    def run(flush):
        eng = CNNStreamEngine(graph, None, plan, microbatch=mb,
                              execute=False)
        for _ in range(n):
            eng.submit(None)
        return eng.run(arrival_rate=arrival, flush_after_ticks=flush)

    held = run(None)
    bounded = run(F(2))
    # without the knob the first frame waits for 3 more arrivals
    # (3 x 8 ticks) before its batch forms; with it, <= 2 ticks + service
    assert held.p99_latency() > 3 * 8
    assert bounded.p99_latency() < 8
    assert bounded.stall_free and bounded.within_queue_bounds
    assert bounded.completed == n
    # every frame still served exactly once, in more (smaller) batches
    assert bounded.completed == held.completed == n
    assert bounded.stages[0].batches_served > held.stages[0].batches_served


def test_flush_none_is_event_identical_to_legacy_run():
    """flush_after_ticks=None must not perturb the event sequence the
    table6 baselines pin (the steppable refactor is behavior-neutral)."""
    _, cfg, graph, plan = _setup("resnet18", n_stages=3)

    def run(**kw):
        eng = CNNStreamEngine(graph, None, plan, microbatch=4,
                              execute=False)
        for _ in range(12):
            eng.submit(None)
        return eng.run(arrival_rate=F(1, 3), **kw)

    a, b = run(), run(flush_after_ticks=None)
    assert a.makespan_ticks == b.makespan_ticks
    assert a.latency_ticks == b.latency_ticks
    assert a.queue_events == b.queue_events


def test_flush_zero_serves_singleton_batches():
    _, cfg, graph, plan = _setup("mobilenet_v2", n_stages=2)
    eng = CNNStreamEngine(graph, None, plan, microbatch=4, execute=False)
    for _ in range(5):
        eng.submit(None)
    rep = eng.run(arrival_rate=F(1, 4), flush_after_ticks=F(0))
    assert rep.stages[0].batches_served == 5  # nothing ever waits
    assert rep.completed == 5


def test_flush_rejects_negative():
    _, cfg, graph, plan = _setup("mobilenet_v2", n_stages=2)
    eng = CNNStreamEngine(graph, None, plan, execute=False)
    eng.submit(None)
    with pytest.raises(ServingError, match="flush_after_ticks"):
        eng.run(flush_after_ticks=F(-1))
