"""The bench-regression gate + benchmarks.run CLI plumbing (jax-free)."""
import json
import pathlib
import re

import pytest

from benchmarks.check_regression import (
    DEFAULT_EXCLUDES, compare, load_rows, main,
)
from benchmarks.run import parse_only


def _rows(*pairs):
    return [{"name": n, "us": 1.0, "derived": d} for n, d in pairs]


def _write(path, rows):
    path.write_text(json.dumps(rows))
    return str(path)


def test_compare_passes_on_identical_derived():
    base = {"a": ["x"], "b": ["y", "y2"]}
    assert compare(base, {"a": ["x"], "b": ["y", "y2"]}) == []


def test_compare_ignores_timing_column(tmp_path):
    base = _write(tmp_path / "b.json", _rows(("a", "x")))
    cur = [{"name": "a", "us": 99999.0, "derived": "x"}]
    current = _write(tmp_path / "c.json", cur)
    assert compare(load_rows(base), load_rows(current)) == []


def test_compare_reports_drift_missing_and_new():
    base = {"a": ["x"], "gone": ["y"]}
    cur = {"a": ["CHANGED"], "new": ["z"]}
    report = "\n".join(compare(base, cur))
    assert "DRIFT" in report and "a" in report
    assert "MISSING" in report and "gone" in report
    assert "NEW" in report and "new" in report


def test_main_exit_codes_and_update(tmp_path):
    base = _write(tmp_path / "base.json", _rows(("a", "x")))
    same = _write(tmp_path / "same.json", _rows(("a", "x")))
    drift = _write(tmp_path / "drift.json", _rows(("a", "CHANGED")))
    assert main(["--baseline", base, "--current", same]) == 0
    assert main(["--baseline", base, "--current", drift]) == 1
    assert main(["--baseline", base, "--current", drift, "--update"]) == 0
    assert main(["--baseline", base, "--current", drift]) == 0  # rebaselined


def test_parse_only_normalizes_case_and_whitespace():
    assert parse_only(" Table1 , TABLE2,table3 ") == {
        "table1", "table2", "table3"}
    assert parse_only("table4") == {"table4"}


def test_parse_only_rejects_unknown_names():
    with pytest.raises(SystemExit, match="tabel1"):
        parse_only("tabel1,table2")
    with pytest.raises(SystemExit, match="unknown"):
        parse_only(" , bogus")


def test_parse_only_rejects_empty_selection():
    """A malformed --only must fail loudly, never run zero benchmarks."""
    for value in (",", " , ", ",,"):
        with pytest.raises(SystemExit, match="no module names"):
            parse_only(value)


def test_update_refuses_to_shrink_baseline(tmp_path):
    """A partial run (module crashed mid-way) must not narrow the gate."""
    base = _write(tmp_path / "base.json", _rows(("a", "x"), ("b", "y")))
    partial = _write(tmp_path / "partial.json", _rows(("a", "x2")))
    assert main(["--baseline", base, "--current", partial, "--update"]) == 1
    assert json.loads((tmp_path / "base.json").read_text()) == _rows(
        ("a", "x"), ("b", "y"))  # untouched


def test_update_refuses_empty_run_and_strips_timing(tmp_path):
    base = _write(tmp_path / "base.json", _rows(("a", "x")))
    empty = _write(tmp_path / "empty.json", [])
    assert main(["--baseline", base, "--current", empty, "--update"]) == 1
    cur = _write(tmp_path / "cur.json", [
        {"name": "a", "us": 123.4, "derived": "y"}])
    assert main(["--baseline", base, "--current", cur, "--update"]) == 0
    rebased = json.loads((tmp_path / "base.json").read_text())
    assert rebased == [{"name": "a", "us": 0.0, "derived": "y"}]


def test_exclude_filters_rows_from_both_sides(tmp_path):
    """Timing rows dropped by --exclude neither drift nor count as NEW."""
    base = _write(tmp_path / "b.json", _rows(
        ("t/analytic", "x"), ("t/timing", "1.23 GMAC/s")))
    cur = _write(tmp_path / "c.json", _rows(
        ("t/analytic", "x"), ("t/timing", "4.56 GMAC/s"),
        ("t/batch_sweep", "b1 9.9")))
    assert main(["--baseline", base, "--current", cur]) == 1  # unfiltered
    assert main(["--baseline", base, "--current", cur,
                 "--exclude", "/timing", "--exclude", "/batch_sweep"]) == 0
    # drift in a *kept* row still fails under the same excludes
    drift = _write(tmp_path / "d.json", _rows(
        ("t/analytic", "CHANGED"), ("t/timing", "7 GMAC/s")))
    assert main(["--baseline", base, "--current", drift,
                 "--exclude", "/timing", "--exclude", "/batch_sweep"]) == 1


def test_exclude_applies_to_update(tmp_path):
    """--update with --exclude never pins excluded rows in the baseline,
    and the shrink check ignores them too."""
    base = _write(tmp_path / "b.json", _rows(("t/analytic", "x")))
    cur = _write(tmp_path / "c.json", _rows(
        ("t/analytic", "y"), ("t/timing", "1.2 GMAC/s")))
    assert main(["--baseline", base, "--current", cur,
                 "--exclude", "/timing", "--update"]) == 0
    rebased = json.loads((tmp_path / "b.json").read_text())
    assert [r["name"] for r in rebased] == ["t/analytic"]


def test_committed_baseline_is_selfconsistent():
    """The committed baseline parses and covers the analytic tables,
    including table4/5's deterministic rows and table6's tick-model
    serving rows, but none of the timing rows the CI gate excludes."""
    repo = pathlib.Path(__file__).resolve().parents[2]
    rows = load_rows(str(repo / "benchmarks" / "baselines"
                         / "analytic_tables.json"))
    prefixes = {name.split("/")[0] for name in rows}
    assert {"table1", "table2", "table3", "table4", "table5",
            "table6"} <= prefixes
    assert sum(len(v) for v in rows.values()) >= 150
    # the CI gate's timing-row patterns must never be pinned in the file
    assert not [n for n in rows
                if any(re.search(u, n) for u in DEFAULT_EXCLUDES)]
